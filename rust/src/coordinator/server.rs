//! Generation server: request queue → scheduler → batched decode loop,
//! with per-request latency accounting. This is the "LLM inference"
//! face of the coordinator — the place where ConSmax's merged β/γ
//! constants actually serve requests.
//!
//! The [`Generator`] is backend-pluggable (the multi-backend seam of
//! DESIGN.md §4):
//!
//! * **native** — KV-cached incremental decode over a
//!   [`DecodeSession`] (one O(T) step per token); always available,
//!   needs no artifacts. `consmax serve-demo --backend native` runs
//!   end-to-end on a machine with nothing but this crate. Rows of a
//!   batch decode **in parallel** across the worker pool
//!   (`runtime::parallel`, sized by `--threads` / `CONSMAX_THREADS`)
//!   with an allocation-free per-row compute path and identical
//!   logits at any thread count. The O(T²) recompute decoder is kept
//!   as the reference oracle and reachable with `--decode recompute`
//!   ([`DecodeMode`]).
//! * **pjrt** (`--features pjrt`) — KV-cached decode over the AOT
//!   `decode_b{N}` executables, parameters uploaded to device buffers
//!   once at construction.
//!
//! Two schedulers drive the [`Server`] (DESIGN.md §Serving seam):
//!
//! * **continuous batching** ([`Server::step`] /
//!   [`Server::run_continuous`], native KV only) — a *persistent*
//!   [`DecodeSession`] slot pool. Requests join a free row mid-flight
//!   (per-row prefill via [`NativeModel::prefill_rows`]), finished rows
//!   free their slot the same step they complete
//!   ([`DecodeSession::reset_row`]), and every tick runs one
//!   `decode_step_active` across whatever mix of in-flight rows exists.
//!   No request ever waits for a co-batched neighbor's budget, and
//!   latency accounting is per request: completion time from
//!   submission, TTFT, and TPOT, never a batch's wall time.
//! * **static batching** ([`Server::run_once`] /
//!   [`Server::run_to_completion`]) — the vLLM-v0-style reference
//!   oracle: pop up to the slot cap, drain the batch to completion.
//!   Kept because its greedy per-request outputs are provably identical
//!   to the continuous scheduler's (`rust/tests/continuous_batching.rs`)
//!   and because the PJRT decode artifacts are lock-step.
//!
//! Batches are **ragged** on the native engine: each row prefills at
//! its own prompt length and is masked to its own cached positions, so
//! a short prompt next to a long one decodes exactly as it would alone
//! (no left-padding, no pad pollution). Requests keep their own
//! temperature, `max_new_tokens` and optional stop token; accounting is
//! in token space.
//!
//! With [`Server::set_kv_config`] the continuous pool runs over the
//! **paged KV-cache subsystem** (DESIGN.md §KV-memory seam): slots
//! become cheap row handles over a shared block pool, capacity is the
//! pool's byte budget (admission by free blocks), requests are
//! whole-request preempted-and-requeued under memory pressure (replay
//! is output-identical thanks to per-request sampler streams), and
//! identical prompt prefixes share refcounted copy-on-write blocks.
//! [`Server::stats`] exposes the occupancy/sharing/preemption gauges.

use std::collections::{HashMap, VecDeque};
use std::marker::PhantomData;
use std::time::Instant;

use anyhow::{bail, ensure, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

use crate::config::{KvCacheConfig, ModelConfig, QuantMode};
use crate::coordinator::params::ParamStore;
use crate::data::ByteTokenizer;
use crate::metrics::LatencyRecorder;
use crate::runtime::backend::{DecodeSession, ExtendLogits, ExtendReq, NativeModel};
use crate::runtime::parallel;
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, HostTensor};
use crate::util::rng::Pcg32;

/// Largest batch the native decode engine serves at once **on the
/// dense KV layout** (a knob, not an export constraint like the PJRT
/// decode artifacts). Sized for the threaded decode loop: rows are the
/// unit of parallelism, so wider batches keep every worker busy.
///
/// With a paged pool ([`Server::set_kv_config`]) this constant stops
/// being the capacity limit: slots are cheap row *handles* and the real
/// bound is the pool's byte budget (`--kv-mem-mb`) — admission is by
/// free blocks, with whole-request preemption under pressure.
pub const NATIVE_MAX_BATCH: usize = 16;

/// Hard ceiling on paged slot-pool size (a sanity bound on per-row
/// scratch, far above any budget a paged pool can serve at once).
pub const MAX_PAGED_SLOTS: usize = 256;

/// Which native decode engine drives generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeMode {
    /// KV-cached incremental decode (the default): prefill once, then
    /// one O(T) `decode_step` per token.
    Kv,
    /// Recompute the ctx-bounded window every step (O(T²) per token) —
    /// the reference oracle, kept as an escape hatch and test anchor.
    Recompute,
}

impl DecodeMode {
    pub fn parse(s: &str) -> Result<DecodeMode> {
        Ok(match s {
            "kv" => DecodeMode::Kv,
            "recompute" => DecodeMode::Recompute,
            other => bail!("unknown decode mode {other:?} (kv|recompute)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DecodeMode::Kv => "kv",
            DecodeMode::Recompute => "recompute",
        }
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling.
    pub temperature: f32,
    /// Optional stop token: generation ends the step this id is
    /// sampled (the stop token itself is not emitted). `None` = run to
    /// `max_new_tokens`.
    pub stop: Option<i32>,
    /// Optional deadline in ms from `submit`: once it lapses the
    /// request is dropped by the continuous scheduler's deadline sweep
    /// — from the queue if still waiting, or mid-flight with its slot
    /// and paged KV blocks freed — and counted `timed_out`. `None` =
    /// no deadline. (The static reference scheduler ignores deadlines;
    /// they are a serving-robustness feature of [`Server::step`].)
    pub deadline_ms: Option<u64>,
}

impl GenRequest {
    /// Greedy, deadline-free request — the common test/bench shape.
    pub fn greedy(id: u64, prompt: impl Into<String>, max_new_tokens: usize) -> GenRequest {
        GenRequest {
            id,
            prompt: prompt.into(),
            max_new_tokens,
            temperature: 0.0,
            stop: None,
            deadline_ms: None,
        }
    }
}

/// A completed response.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    /// The generated token ids (`text` is their byte-decoded form).
    pub tokens: Vec<i32>,
    /// Post-clamp encoded prompt length (tokens actually attended).
    pub prompt_tokens: usize,
    /// Generated tokens (== `text` in bytes for the byte tokenizer,
    /// but counted in token space, never `chars()`).
    pub new_tokens: usize,
    /// Per-request completion time in ms, measured from `submit` to
    /// *this row* finishing — queue wait included, never a co-batched
    /// neighbor's drain time.
    pub latency_ms: f64,
    /// Time to first token in ms, from `submit`. Under static batching
    /// this equals `latency_ms`: nothing streams before the batch
    /// drains.
    pub ttft_ms: f64,
    /// Requests co-resident when this one completed (static batching:
    /// the batch size it was served in).
    pub batch_size: usize,
    /// Draft tokens proposed for this request by self-speculative
    /// decoding (0 when `--spec` is off or the request never specced).
    pub spec_proposed: u64,
    /// Draft proposals the target model accepted; `spec_accepted /
    /// spec_proposed` is the request's acceptance rate.
    pub spec_accepted: u64,
}

/// One batch's generation output, in token space.
pub struct GenOutput {
    /// Newly generated token ids per row (exactly `max_new[r]` each).
    pub tokens: Vec<Vec<i32>>,
    /// The same tokens decoded to text per row.
    pub texts: Vec<String>,
    /// Post-clamp encoded prompt length per row.
    pub prompt_tokens: Vec<usize>,
}

/// Backend-specific decode state.
enum GenExec<'e> {
    /// Native decode over the pure-Rust model (KV-cached or recompute).
    Native {
        model: Box<NativeModel>,
        mode: DecodeMode,
        _lt: PhantomData<&'e ()>,
    },
    /// KV-cached decode over the AOT `decode_b{N}` executables.
    #[cfg(feature = "pjrt")]
    Pjrt {
        engine: &'e Engine,
        /// Parameters cached as device buffers: uploaded once at
        /// construction instead of on every decode step (§Perf: removes
        /// the dominant per-step cost, a full-model host→device copy).
        params: Vec<xla::PjRtBuffer>,
        /// Decode batch sizes available in the manifest, descending.
        batch_sizes: Vec<usize>,
    },
}

/// Batched generator over a decode backend.
pub struct Generator<'e> {
    pub cfg: ModelConfig,
    exec: GenExec<'e>,
    rng: Pcg32,
    /// Seed the generator was built with; the continuous scheduler
    /// derives a per-request sampler stream from it (`seed` × request
    /// id), so a sampled request's output never depends on which
    /// neighbors happened to share its decode steps.
    seed: u64,
}

impl<'e> Generator<'e> {
    /// PJRT-backed generator over an engine's decode artifacts.
    #[cfg(feature = "pjrt")]
    pub fn new(engine: &'e Engine, store: &ParamStore, seed: u64) -> Result<Generator<'e>> {
        let cfg = engine.manifest.config(&store.config_key)?.clone();
        let params = store
            .params
            .iter()
            .map(|t| engine.upload(t))
            .collect::<Result<_>>()?;
        let mut batch_sizes: Vec<usize> = engine
            .manifest
            .entries
            .keys()
            .filter_map(|name| {
                name.strip_prefix(&format!("{}_decode_b", cfg.key))
                    .and_then(|b| b.parse().ok())
            })
            .collect();
        batch_sizes.sort_unstable_by(|a, b| b.cmp(a));
        if batch_sizes.is_empty() {
            bail!("no decode artifacts for {} (re-run `make artifacts`)", cfg.key);
        }
        Ok(Generator {
            cfg,
            exec: GenExec::Pjrt { engine, params, batch_sizes },
            rng: Pcg32::seeded(seed),
            seed,
        })
    }

    /// Native generator with the default KV-cached decode engine.
    pub fn native(
        cfg: &ModelConfig,
        store: &ParamStore,
        seed: u64,
    ) -> Result<Generator<'static>> {
        Generator::native_with(cfg, store, seed, DecodeMode::Kv)
    }

    /// Native generator with an explicit decode engine (`--decode`).
    pub fn native_with(
        cfg: &ModelConfig,
        store: &ParamStore,
        seed: u64,
        mode: DecodeMode,
    ) -> Result<Generator<'static>> {
        Generator::native_quant(cfg, store, seed, mode, QuantMode::Off)
    }

    /// Native generator with an explicit decode engine and serving
    /// quantization mode (`--decode` / `--quant`). Under
    /// [`QuantMode::Int8`] the model quantizes its projection weights
    /// and LM head per channel at load and routes the ConSmax attention
    /// tail through the bit-split LUT (DESIGN.md §Quantization seam).
    pub fn native_quant(
        cfg: &ModelConfig,
        store: &ParamStore,
        seed: u64,
        mode: DecodeMode,
        quant: QuantMode,
    ) -> Result<Generator<'static>> {
        let model = NativeModel::from_params_quant(
            cfg,
            &store.order,
            &store.params,
            quant,
        )?;
        Ok(Generator {
            cfg: cfg.clone(),
            exec: GenExec::Native {
                model: Box::new(model),
                mode,
                _lt: PhantomData,
            },
            rng: Pcg32::seeded(seed),
            seed,
        })
    }

    /// Which backend this generator decodes on ("native" / "pjrt").
    pub fn backend_name(&self) -> &'static str {
        match &self.exec {
            GenExec::Native { .. } => "native",
            #[cfg(feature = "pjrt")]
            GenExec::Pjrt { .. } => "pjrt",
        }
    }

    /// Which decode engine runs under the backend ("kv" / "recompute").
    pub fn decode_name(&self) -> &'static str {
        match &self.exec {
            GenExec::Native { mode, .. } => mode.name(),
            #[cfg(feature = "pjrt")]
            GenExec::Pjrt { .. } => "kv",
        }
    }

    /// The serving quantization mode under the backend ("off" / "int8").
    pub fn quant_name(&self) -> &'static str {
        match &self.exec {
            GenExec::Native { model, .. } => model.quant_mode().name(),
            #[cfg(feature = "pjrt")]
            GenExec::Pjrt { .. } => "off",
        }
    }

    pub fn max_batch(&self) -> usize {
        match &self.exec {
            GenExec::Native { .. } => NATIVE_MAX_BATCH,
            #[cfg(feature = "pjrt")]
            GenExec::Pjrt { batch_sizes, .. } => batch_sizes[0],
        }
    }

    /// Can this generator drive the continuous-batching scheduler?
    /// Native KV only: the PJRT decode artifacts are lock-step over a
    /// fixed batch, and the recompute oracle has no persistent session
    /// for requests to join mid-flight.
    pub fn supports_continuous(&self) -> bool {
        matches!(&self.exec, GenExec::Native { mode: DecodeMode::Kv, .. })
    }

    /// Encode prompts in token space, clamping each row to its own
    /// KV/ctx budget (`ctx - max_new[r]`). Rows stay **ragged** — no
    /// padding; per-row lengths are respected by the decode engines.
    /// Returns the rows plus each row's post-clamp token count (what
    /// accounting must report, not the prompt's byte length). An empty
    /// prompt is seeded with a single space so decoding has a position
    /// to condition on.
    fn encode_prompts(
        &self,
        prompts: &[String],
        max_new: &[usize],
    ) -> (Vec<Vec<i32>>, Vec<usize>) {
        let tok = ByteTokenizer;
        let mut encoded = Vec::with_capacity(prompts.len());
        let mut prompt_tokens = Vec::with_capacity(prompts.len());
        for (p, &mn) in prompts.iter().zip(max_new) {
            let budget = self.cfg.ctx.saturating_sub(mn).max(1);
            let mut t = tok.encode(p);
            if t.len() > budget {
                t = t.split_off(t.len() - budget);
            }
            if t.is_empty() {
                t.push(b' ' as i32);
            }
            prompt_tokens.push(t.len());
            encoded.push(t);
        }
        (encoded, prompt_tokens)
    }

    /// Generate continuations for up to `max_batch()` prompts at once,
    /// one shared `max_new`/temperature (convenience wrapper over
    /// [`Generator::generate_batch_ext`]). The returned strings contain
    /// only the newly generated text.
    pub fn generate_batch(
        &mut self,
        prompts: &[String],
        max_new: usize,
        temperature: f32,
    ) -> Result<Vec<String>> {
        let out = self.generate_batch_ext(
            prompts,
            &vec![max_new; prompts.len()],
            &vec![temperature; prompts.len()],
        )?;
        Ok(out.texts)
    }

    /// Generate continuations with **per-row** token budgets and
    /// temperatures — the static-batch serving entry point. Row `r`
    /// receives exactly `max_new[r]` tokens sampled at
    /// `temperature[r]`; accounting in the returned [`GenOutput`] is
    /// entirely in token space.
    pub fn generate_batch_ext(
        &mut self,
        prompts: &[String],
        max_new: &[usize],
        temperature: &[f32],
    ) -> Result<GenOutput> {
        ensure!(!prompts.is_empty(), "empty batch");
        ensure!(
            prompts.len() == max_new.len() && prompts.len() == temperature.len(),
            "per-row max_new/temperature must match the prompt count"
        );
        ensure!(
            prompts.len() <= self.max_batch(),
            "batch of {} exceeds max decode batch {}",
            prompts.len(),
            self.max_batch()
        );
        #[cfg_attr(not(feature = "pjrt"), allow(unused_mut))]
        let (encoded, mut prompt_tokens) = self.encode_prompts(prompts, max_new);
        let tok = ByteTokenizer;
        let b = prompts.len();
        let vocab = self.cfg.vocab;
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); b];
        match &mut self.exec {
            GenExec::Native { model, mode, .. } => match *mode {
                DecodeMode::Kv => {
                    let mut sess = DecodeSession::new(&self.cfg, b);
                    let logits = model.prefill(&mut sess, &encoded)?;
                    let mut last = vec![0i32; b];
                    for r in 0..b {
                        if max_new[r] == 0 {
                            continue;
                        }
                        let row = &logits[r * vocab..(r + 1) * vocab];
                        let next = pick_token(row, temperature[r], &mut self.rng);
                        generated[r].push(next);
                        last[r] = next;
                    }
                    loop {
                        let active: Vec<bool> =
                            (0..b).map(|r| generated[r].len() < max_new[r]).collect();
                        if !active.iter().any(|&a| a) {
                            break;
                        }
                        let logits =
                            model.decode_step_active(&mut sess, &last, &active)?;
                        for r in 0..b {
                            if !active[r] {
                                continue;
                            }
                            let row = &logits[r * vocab..(r + 1) * vocab];
                            let next =
                                pick_token(row, temperature[r], &mut self.rng);
                            generated[r].push(next);
                            last[r] = next;
                        }
                    }
                }
                DecodeMode::Recompute => {
                    // the oracle path: rows decode independently, so a
                    // ragged batch needs no padding here either
                    for r in 0..b {
                        let mut seq = encoded[r].clone();
                        for _ in 0..max_new[r] {
                            let logits =
                                model.next_logits(std::slice::from_ref(&seq))?;
                            let next =
                                pick_token(&logits, temperature[r], &mut self.rng);
                            seq.push(next);
                            generated[r].push(next);
                        }
                    }
                }
            },
            #[cfg(feature = "pjrt")]
            GenExec::Pjrt { engine, params, batch_sizes } => {
                // a batch whose every budget is zero has nothing to
                // decode: without this early exit the loop below would
                // still run `plen` steps and sample into nothing (the
                // native paths already skip their loops)
                let max_new_cap = max_new.iter().copied().max().unwrap_or(0);
                if max_new_cap > 0 {
                    // smallest exported batch size that fits the request count
                    let bq = *batch_sizes
                        .iter()
                        .filter(|&&bs| bs >= b)
                        .min()
                        .unwrap_or(&batch_sizes[0]);
                    let entry = format!("{}_decode_b{}", self.cfg.key, bq);
                    let exe = engine.load(&entry)?;

                    // the AOT decode step is lock-step, so the deepest
                    // generation budget in the batch defines the shared
                    // prompt window: without this re-clamp, a long prompt
                    // (clamped only by its own small max_new) would push
                    // plen + max_new_cap past ctx and silently truncate the
                    // high-budget rows
                    let cap_budget =
                        self.cfg.ctx.saturating_sub(max_new_cap).max(1);
                    let mut encoded = encoded;
                    for (t, pt) in encoded.iter_mut().zip(prompt_tokens.iter_mut())
                    {
                        if t.len() > cap_budget {
                            *t = t.split_off(t.len() - cap_budget);
                            *pt = t.len();
                        }
                    }

                    // left-pad to a common length (per-row masking is a
                    // native-engine feature); rows beyond the real prompts
                    // replicate row 0 (outputs ignored)
                    let plen =
                        encoded.iter().map(Vec::len).max().unwrap_or(1).max(1);
                    for t in encoded.iter_mut() {
                        while t.len() < plen {
                            t.insert(0, b' ' as i32);
                        }
                    }
                    while encoded.len() < bq {
                        encoded.push(encoded[0].clone());
                    }

                    // KV caches start zeroed (device-resident; re-uploaded per
                    // step because the output tuple only materializes on host)
                    let cache_shape = vec![
                        self.cfg.n_layer,
                        bq,
                        self.cfg.n_head,
                        self.cfg.ctx,
                        self.cfg.head_dim(),
                    ];
                    let mut kc = engine.upload(&HostTensor::zeros(
                        crate::runtime::DType::F32,
                        &cache_shape,
                    ))?;
                    let mut vc = engine.upload(&HostTensor::zeros(
                        crate::runtime::DType::F32,
                        &cache_shape,
                    ))?;

                    // plen <= ctx - max_new_cap, so every row completes its
                    // budget before the ctx guard below can fire
                    let steps = plen + max_new_cap - 1;
                    let mut last_tokens: Vec<i32> =
                        encoded.iter().map(|t| t[0]).collect();

                    for pos in 0..=steps {
                        if pos >= self.cfg.ctx {
                            break;
                        }
                        let toks: Vec<i32> = (0..bq)
                            .map(|r| {
                                if pos < plen {
                                    encoded[r][pos]
                                } else {
                                    last_tokens[r]
                                }
                            })
                            .collect();
                        let tok_buf =
                            engine.upload(&HostTensor::from_i32(&toks, &[bq]))?;
                        let pos_buf =
                            engine.upload(&HostTensor::scalar_i32(pos as i32))?;
                        let inputs: Vec<&xla::PjRtBuffer> = params
                            .iter()
                            .chain([&kc, &vc, &pos_buf, &tok_buf])
                            .collect();
                        let mut outs =
                            engine.execute_buffer_refs(&entry, &exe, &inputs)?;
                        vc = engine.upload_literal(&outs.pop().context("vc")?)?;
                        kc = engine.upload_literal(&outs.pop().context("kc")?)?;
                        let logits_t = HostTensor::from_literal(
                            &outs.pop().context("logits")?,
                        )?;
                        let logits = logits_t.as_f32()?;

                        if pos + 1 >= plen {
                            // sample the next token per row, at that row's
                            // own temperature, up to its own budget
                            for r in 0..b {
                                let row = &logits[r * vocab..(r + 1) * vocab];
                                let next = pick_token(
                                    row,
                                    temperature[r],
                                    &mut self.rng,
                                );
                                last_tokens[r] = next;
                                if generated[r].len() < max_new[r] {
                                    generated[r].push(next);
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(GenOutput {
            texts: generated.iter().map(|g| tok.decode(g)).collect(),
            tokens: generated,
            prompt_tokens,
        })
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        // NaN never wins a comparison, so a NaN incumbent must be
        // displaced explicitly or a row like [NaN, inf] would return 0
        if xs[best].is_nan() || v > xs[best] {
            best = i;
        }
    }
    best
}

fn sample_temperature(logits: &[f32], temp: f32, rng: &mut Pcg32) -> usize {
    // Degenerate rows used to kill the whole server: Pcg32::weighted
    // asserts positive mass, so a logit row that is all non-finite (or
    // one whose weights under/overflow at extreme temperatures) was a
    // panic, not a bad sample. Fall back to greedy argmax instead.
    if logits.iter().any(|&l| l == f32::INFINITY) {
        return argmax(logits); // +inf spike: it wins outright
    }
    let m = logits
        .iter()
        .cloned()
        .filter(|v| v.is_finite())
        .fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return argmax(logits); // no finite logit anywhere in the row
    }
    let weights: Vec<f64> = logits
        .iter()
        .map(|&l| {
            if l.is_finite() {
                (((l - m) / temp) as f64).exp()
            } else {
                0.0 // -inf / NaN entries carry no mass
            }
        })
        .collect();
    let total: f64 = weights.iter().sum();
    if !total.is_finite() || total <= 0.0 {
        return argmax(logits);
    }
    rng.weighted(&weights)
}

/// Sample one token: greedy at `temperature <= 0`, else softmax-tempered.
fn pick_token(row: &[f32], temperature: f32, rng: &mut Pcg32) -> i32 {
    if temperature <= 0.0 {
        argmax(row) as i32
    } else {
        sample_temperature(row, temperature, rng) as i32
    }
}

/// Whether `req`'s deadline (relative to its submit time) has lapsed.
fn deadline_passed(req: &GenRequest, submitted: Instant, now: Instant) -> bool {
    req.deadline_ms
        .is_some_and(|d| now.duration_since(submitted).as_millis() as u64 >= d)
}

/// A queued request plus its arrival time (latency accounting starts
/// at `submit`, so queue wait is part of every reported latency).
struct Pending {
    req: GenRequest,
    submitted: Instant,
}

/// Self-speculative decoding configuration (`--spec draft-k=K`): a
/// small builtin draft model proposes `draft_k` greedy tokens per
/// resident row each tick; one batched target extension verifies them
/// all, and the longest matched prefix (plus the target's own bonus
/// token) is accepted. Greedy acceptance keeps outputs bit-identical
/// to the non-speculative oracle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecConfig {
    /// Draft tokens proposed (and verified) per row per round.
    pub draft_k: usize,
}

/// The draft side of self-speculative decoding: the draft model plus
/// its config (the draft `DecodeSession` lives in [`ContState`] so its
/// lifecycle is tied to the slot pool's).
struct DraftState {
    model: Box<NativeModel>,
    cfg: ModelConfig,
}

/// Per-row scheduling phase in the continuous pool. Rows only dwell in
/// `Prefill` under chunked prefill (`--prefill-chunk N`); monolithic
/// prefill lands a row directly in `Decode` on its join tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Prompt ingestion in progress: `fed` prompt tokens are cached.
    Prefill { fed: usize },
    /// Prompt fully cached; the row emits one token per decode step
    /// (or several per speculative round).
    Decode,
}

/// What one tick plans to do with one occupied row (computed once per
/// tick, after joiner prefill, and used both by the paged preemption
/// pass to price the tick's worst-case block demand and by the
/// execution stages below it).
#[derive(Debug, Clone, Copy)]
enum RowPlan {
    /// Feed the next `len` prompt tokens; `completes` when this chunk
    /// is the prompt's last (the row samples its first token and joins
    /// this same tick's decode step).
    Chunk { len: usize, completes: bool },
    /// Run a speculative round proposing and verifying `k` draft tokens.
    Spec { k: usize },
    /// Plain single-token decode step.
    Decode,
}

/// One occupied row of the continuous-batching slot pool.
struct Slot {
    req: GenRequest,
    submitted: Instant,
    /// Encoded (post-clamp) prompt, kept for the join-step prefill.
    prompt: Vec<i32>,
    prompt_tokens: usize,
    first_token_at: Option<Instant>,
    generated: Vec<i32>,
    last: i32,
    done: bool,
    /// Per-request sampler stream (seeded from the generator seed and
    /// the request id): sampled output is independent of co-batched
    /// neighbors, exactly like greedy output. This is also what makes
    /// paged preempt-and-requeue output-preserving: a restarted request
    /// re-derives the same stream and regenerates the same tokens.
    rng: Pcg32,
    /// Monotone admission counter: preemption evicts the youngest.
    join_seq: u64,
    /// Scheduling phase: `Prefill { fed }` while prompt chunks are
    /// still landing (chunked prefill only), then `Decode`.
    phase: Phase,
    /// Draft-cache bookkeeping for self-speculative decoding: the
    /// draft session's row holds a trailing window of the first
    /// `draft_cached` committed tokens (prompt ++ generated). 0 = the
    /// draft row is cold and must be (re)prefilled before proposing.
    draft_cached: usize,
    /// Draft tokens proposed for this request (observability).
    spec_proposed: u64,
    /// Draft proposals the target accepted (observability).
    spec_accepted: u64,
}

impl Slot {
    /// Account one sampled token: stop-token and budget checks.
    fn feed(&mut self, tok: i32, now: Instant) {
        if self.first_token_at.is_none() {
            self.first_token_at = Some(now);
        }
        if self.req.stop == Some(tok) {
            self.done = true; // stop token itself is not emitted
            return;
        }
        self.generated.push(tok);
        self.last = tok;
        if self.generated.len() >= self.req.max_new_tokens {
            self.done = true;
        }
    }
}

/// Persistent continuous-batching state: one `DecodeSession` whose rows
/// are serving slots. `slots[i] == None` ⇔ row `i` is free. Under
/// `--spec` a second (always dense) session holds the draft model's KV
/// rows, slot-for-slot with the target's.
struct ContState {
    sess: DecodeSession,
    slots: Vec<Option<Slot>>,
    draft_sess: Option<DecodeSession>,
}

impl ContState {
    /// Free row `i`: take its slot and reset both the target row and
    /// (when speculating) the draft row. Every release path — harvest,
    /// cancel, deadline sweep, preemption — must come through here so
    /// draft KV state can never outlive its request.
    fn release(&mut self, i: usize) -> Option<Slot> {
        let s = self.slots[i].take();
        if s.is_some() {
            self.sess.reset_row(i);
            if let Some(d) = self.draft_sess.as_mut() {
                d.reset_row(i);
            }
        }
        s
    }
}

/// Emit a [`ServeEvent::Token`] for position `pos` of request `id`,
/// deduped by the per-request high-water mark (replays after
/// preemption / panic recovery re-feed earlier positions). Free
/// function so call sites can hold disjoint borrows into the server.
fn emit_token_event(
    events: &mut Option<Vec<ServeEvent>>,
    watermark: &mut HashMap<u64, usize>,
    id: u64,
    pos: usize,
    tok: i32,
) {
    if events.is_none() {
        return;
    }
    let wm = watermark.entry(id).or_insert(0);
    if pos > *wm {
        *wm = pos;
        if let Some(buf) = events.as_mut() {
            buf.push(ServeEvent::Token { id, token: tok });
        }
    }
}

/// Token `j` of a slot's committed sequence (prompt ++ generated).
fn committed_token(s: &Slot, j: usize) -> i32 {
    if j < s.prompt.len() {
        s.prompt[j]
    } else {
        s.generated[j - s.prompt.len()]
    }
}

/// Compute this tick's per-row plan (see [`RowPlan`]). `joins` are the
/// rows admitted *this* tick: their first prompt chunk (or monolithic
/// prefill) already ran, so they neither chunk again nor speculate
/// until the next tick.
fn plan_rows(
    cont: &ContState,
    joins: &[usize],
    chunk: Option<usize>,
    spec: Option<SpecConfig>,
    ctx: usize,
) -> Vec<Option<RowPlan>> {
    cont.slots
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let s = s.as_ref()?;
            if s.done {
                return None;
            }
            match s.phase {
                Phase::Prefill { .. } if joins.contains(&i) => None,
                Phase::Prefill { fed } => {
                    let plen = s.prompt.len();
                    let c = chunk
                        .expect("Prefill phase only exists under chunking")
                        .min(plen - fed);
                    Some(RowPlan::Chunk { len: c, completes: fed + c == plen })
                }
                Phase::Decode => {
                    if let Some(sp) = spec {
                        // greedy rows only (acceptance compares argmaxes),
                        // never on the join tick, and only when at least
                        // one proposal fits both the remaining budget
                        // (k + 1 emitted tokens max) and the target ctx
                        // (k + 1 more cached positions; rows at the
                        // eviction boundary fall back to plain decode)
                        if s.req.temperature == 0.0 && !joins.contains(&i) {
                            let len = cont.sess.len_of(i);
                            let budget_room = s
                                .req
                                .max_new_tokens
                                .saturating_sub(s.generated.len() + 1);
                            let ctx_room = ctx.saturating_sub(len + 1);
                            let k = sp.draft_k.min(budget_room).min(ctx_room);
                            if k >= 1 {
                                return Some(RowPlan::Spec { k });
                            }
                        }
                    }
                    Some(RowPlan::Decode)
                }
            }
        })
        .collect()
}

/// What a scheduler hands to `Server::finish` when a request completes.
struct Done {
    id: u64,
    tokens: Vec<i32>,
    /// Precomputed `decode(tokens)`, when the caller already has it
    /// (`None` ⇒ `finish` decodes).
    text: Option<String>,
    prompt_tokens: usize,
    submitted: Instant,
    first_token_at: Option<Instant>,
    batch_size: usize,
    spec_proposed: u64,
    spec_accepted: u64,
}

/// Admission verdict from [`Server::try_submit`] (bounded ingress).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    Admitted,
    /// Load-shed: queue depth or estimated TTFT crossed the configured
    /// limit. `retry_after_ms` is the backoff hint a front end should
    /// surface (HTTP `Retry-After`). The request was **not** enqueued;
    /// it is counted `submitted` and `shed`.
    Shed { retry_after_ms: u64 },
}

/// Per-request lifecycle notification from the continuous scheduler,
/// captured when [`Server::set_event_capture`] is on (the streaming
/// front end's feed; off by default so in-process callers pay nothing).
///
/// After a preemption or a recovered worker panic a replayed request
/// re-emits its `Token` events from the start; replay is
/// output-identical (per-request sampler streams), so streaming
/// consumers dedupe by position, not content.
#[derive(Debug, Clone)]
pub enum ServeEvent {
    /// One newly generated token on an in-flight request.
    Token { id: u64, token: i32 },
    /// Terminal: the request completed and produced a response.
    Completed(GenResponse),
    /// Terminal: the deadline sweep dropped the request.
    TimedOut { id: u64 },
    /// Terminal: [`Server::cancel`] dropped the request.
    Cancelled { id: u64 },
}

impl ServeEvent {
    /// The request this event belongs to.
    pub fn id(&self) -> u64 {
        match self {
            ServeEvent::Token { id, .. }
            | ServeEvent::TimedOut { id }
            | ServeEvent::Cancelled { id } => *id,
            ServeEvent::Completed(r) => r.id,
        }
    }
}

/// Request-queue server over a [`Generator`], with two schedulers: the
/// continuous-batching slot pool ([`Server::step`]) and the static
/// reference batcher ([`Server::run_once`]). See the module docs for
/// when each applies.
///
/// **Terminal-state accounting.** Every request that enters through
/// [`Server::submit`] / [`Server::try_submit`] increments `submitted`
/// and ends in exactly one of four terminal counters: `completed`
/// (response produced), `shed` (bounced at admission), `timed_out`
/// (deadline sweep), or `cancelled` ([`Server::cancel`]). The chaos
/// suite (`rust/tests/chaos_serving.rs`) pins
/// `completed + shed + timed_out + cancelled == submitted` across
/// randomized churn with faults injected at every seam.
pub struct Server<'e> {
    pub generator: Generator<'e>,
    queue: VecDeque<Pending>,
    /// Serving slot cap: `min(backend max batch, set_max_batch(..))`.
    max_batch: usize,
    /// Per-request completion latency from `submit` (µs).
    pub latencies: LatencyRecorder,
    /// Per-request time to first token from `submit` (µs).
    pub ttft: LatencyRecorder,
    /// Per-request time per output token during decode (µs/token).
    pub tpot: LatencyRecorder,
    /// Requests accepted by `submit` or judged by `try_submit` (shed
    /// ones included: a shed is a terminal state, not a non-event).
    pub submitted: u64,
    pub completed: u64,
    pub tokens_out: u64,
    /// Requests bounced at admission (`try_submit` over the limits).
    pub shed: u64,
    /// Requests dropped by the deadline sweep.
    pub timed_out: u64,
    /// Requests dropped by [`Server::cancel`].
    pub cancelled: u64,
    /// Decode/prefill worker panics contained and recovered from (all
    /// residents requeued, session rebuilt, outputs replay-identical).
    pub panics_recovered: u64,
    /// Whole-request preemptions under paged memory pressure (each one
    /// re-queued at the front and replayed deterministically).
    pub preemptions: u64,
    /// Draft tokens proposed across all requests (`--spec`).
    pub spec_proposed: u64,
    /// Draft proposals the target model accepted across all requests.
    pub spec_accepted: u64,
    /// Prompt-chunk feeds executed by the chunked-prefill path (one per
    /// row per chunk, first chunks included; 0 when `--prefill-chunk`
    /// is off).
    pub prefill_chunk_steps: u64,
    /// Batched `decode_step_active` invocations (ticks that advanced at
    /// least one row by plain decode).
    pub decode_steps: u64,
    cont: Option<ContState>,
    /// Chunked-prefill size (`--prefill-chunk N`); `None` = monolithic
    /// prompt ingestion (the legacy path, byte-identical behavior).
    prefill_chunk: Option<usize>,
    /// Self-speculative decoding config; `None` = off.
    spec: Option<SpecConfig>,
    /// The draft model (present iff `spec` is).
    draft: Option<DraftState>,
    /// Paged-KV configuration for the continuous slot pool (None =
    /// dense per-row caches, the original layout).
    kv: Option<KvCacheConfig>,
    /// Bounded-ingress knobs (`set_admission_limits`): max queue depth
    /// and max estimated TTFT before `try_submit` sheds.
    queue_cap: Option<usize>,
    ttft_limit_ms: Option<f64>,
    /// Lifecycle event buffer; `None` = capture off (the default).
    events: Option<Vec<ServeEvent>>,
    /// Per-request token high-water mark (capture only): a preempted or
    /// panic-recovered request replays its generation from scratch, and
    /// replay is bit-identical, so re-fed positions at or below the
    /// mark are suppressed — [`ServeEvent::Token`] is exactly-once per
    /// token position. Entries drop at the request's terminal state.
    token_watermark: HashMap<u64, usize>,
    next_join_seq: u64,
}

/// One snapshot of the server's serving gauges (`Server::stats`):
/// queue/pool occupancy plus the paged-KV block gauges (zero when the
/// pool is dense).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub pending: usize,
    pub in_flight: usize,
    /// All requests that entered admission (shed ones included); at
    /// drain, `completed + shed + timed_out + cancelled == submitted`.
    pub submitted: u64,
    pub completed: u64,
    pub tokens_out: u64,
    pub shed: u64,
    pub timed_out: u64,
    pub cancelled: u64,
    pub panics_recovered: u64,
    pub preemptions: u64,
    pub kv_paged: bool,
    pub kv_total_blocks: usize,
    pub kv_free_blocks: usize,
    /// Blocks referenced by more than one row (prefix sharing at work).
    pub kv_shared_blocks: usize,
    pub kv_block_tokens: usize,
    /// Draft tokens proposed by self-speculative decoding (`--spec`).
    pub spec_proposed: u64,
    /// Draft proposals the target accepted; `spec_accepted /
    /// spec_proposed` is the aggregate acceptance rate.
    pub spec_accepted: u64,
    /// Prompt-chunk feeds executed by chunked prefill
    /// (`--prefill-chunk`; 0 when off).
    pub prefill_chunk_steps: u64,
    /// Batched decode steps executed (ticks advancing ≥1 row).
    pub decode_steps: u64,
}

impl<'e> Server<'e> {
    pub fn new(generator: Generator<'e>) -> Server<'e> {
        let max_batch = generator.max_batch();
        Server {
            generator,
            queue: VecDeque::new(),
            max_batch,
            latencies: LatencyRecorder::default(),
            ttft: LatencyRecorder::default(),
            tpot: LatencyRecorder::default(),
            submitted: 0,
            completed: 0,
            tokens_out: 0,
            shed: 0,
            timed_out: 0,
            cancelled: 0,
            panics_recovered: 0,
            preemptions: 0,
            spec_proposed: 0,
            spec_accepted: 0,
            prefill_chunk_steps: 0,
            decode_steps: 0,
            cont: None,
            prefill_chunk: None,
            spec: None,
            draft: None,
            kv: None,
            queue_cap: None,
            ttft_limit_ms: None,
            events: None,
            token_watermark: HashMap::new(),
            next_join_seq: 0,
        }
    }

    /// Unconditional enqueue (the in-process path: benches, tests, the
    /// demo drivers). Network front ends admit through [`try_submit`]
    /// instead, which honors the bounded-ingress limits.
    ///
    /// [`try_submit`]: Server::try_submit
    pub fn submit(&mut self, req: GenRequest) {
        self.submitted += 1;
        self.queue.push_back(Pending { req, submitted: Instant::now() });
    }

    /// Configure bounded ingress for [`Server::try_submit`]: shed once
    /// the queue holds `queue_cap` requests, or once the estimated TTFT
    /// of a new admission crosses `ttft_limit_ms`. `None` disables the
    /// respective limit (the default: never shed).
    pub fn set_admission_limits(
        &mut self,
        queue_cap: Option<usize>,
        ttft_limit_ms: Option<f64>,
    ) {
        self.queue_cap = queue_cap;
        self.ttft_limit_ms = ttft_limit_ms;
    }

    /// Coarse estimate of a new admission's TTFT in ms: the mean
    /// observed TTFT scaled by how many queue "generations" (of
    /// `max_batch` requests) are already waiting ahead of it. `None`
    /// until a first TTFT sample exists (a cold server never sheds on
    /// the estimate — it has no evidence of being slow).
    pub fn estimated_ttft_ms(&self) -> Option<f64> {
        if self.ttft.len() == 0 {
            return None;
        }
        let waves = 1.0 + self.queue.len() as f64 / self.max_batch as f64;
        Some(self.ttft.mean() / 1e3 * waves)
    }

    /// Bounded admission: enqueue the request unless a configured limit
    /// ([`Server::set_admission_limits`]) says the server is overloaded,
    /// in which case the request is **shed** — counted `submitted` +
    /// `shed`, never enqueued — and the caller gets a Retry-After hint.
    /// With no limits configured this is exactly [`Server::submit`].
    pub fn try_submit(&mut self, req: GenRequest) -> Admission {
        let over_depth =
            self.queue_cap.is_some_and(|cap| self.queue.len() >= cap);
        let over_ttft = match (self.ttft_limit_ms, self.estimated_ttft_ms()) {
            (Some(limit), Some(est)) => est > limit,
            _ => false,
        };
        if over_depth || over_ttft {
            self.submitted += 1;
            self.shed += 1;
            // back off for about one queue drain; clamped to something
            // a client can reasonably honor
            let hint = self.estimated_ttft_ms().unwrap_or(100.0);
            return Admission::Shed {
                retry_after_ms: (hint.ceil() as u64).clamp(50, 10_000),
            };
        }
        self.submit(req);
        Admission::Admitted
    }

    /// Cancel a request wherever it currently lives: still queued (the
    /// entry is removed) or resident in the continuous pool (the slot
    /// and its paged KV blocks are freed mid-flight, exactly like the
    /// harvest path). Returns whether the id was found; a found request
    /// is counted `cancelled` — its terminal state — and emits a
    /// [`ServeEvent::Cancelled`]. This is the client-disconnect path of
    /// the network front end.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(pos) = self.queue.iter().position(|p| p.req.id == id) {
            self.queue.remove(pos);
            self.cancelled += 1;
            self.token_watermark.remove(&id);
            self.push_event(ServeEvent::Cancelled { id });
            return true;
        }
        let mut hit = false;
        if let Some(cont) = self.cont.as_mut() {
            for i in 0..cont.slots.len() {
                if matches!(&cont.slots[i], Some(s) if s.req.id == id) {
                    cont.release(i);
                    hit = true;
                    break;
                }
            }
        }
        if hit {
            self.cancelled += 1;
            self.token_watermark.remove(&id);
            self.push_event(ServeEvent::Cancelled { id });
        }
        hit
    }

    /// Toggle lifecycle-event capture ([`ServeEvent`]); turning it on
    /// (or off) resets the buffer. Off by default.
    pub fn set_event_capture(&mut self, on: bool) {
        self.events = if on { Some(Vec::new()) } else { None };
        self.token_watermark.clear();
    }

    /// Take every event captured since the last drain (empty when
    /// capture is off).
    pub fn drain_events(&mut self) -> Vec<ServeEvent> {
        self.events.as_mut().map(std::mem::take).unwrap_or_default()
    }

    fn push_event(&mut self, ev: ServeEvent) {
        if let Some(buf) = self.events.as_mut() {
            buf.push(ev);
        }
    }

    /// Drop every request whose deadline lapsed: queued entries before
    /// they ever take a slot, residents mid-flight with their row and
    /// paged KV blocks freed (the same release path as harvest). Rows
    /// that already finished generating this tick are left for harvest
    /// — they completed inside their deadline. Runs at the top of every
    /// [`Server::step`], beside the preemption pass.
    fn sweep_deadlines(&mut self, now: Instant) {
        let mut expired: Vec<u64> = Vec::new();
        self.queue.retain(|p| {
            let lapsed = deadline_passed(&p.req, p.submitted, now);
            if lapsed {
                expired.push(p.req.id);
            }
            !lapsed
        });
        if let Some(cont) = self.cont.as_mut() {
            for i in 0..cont.slots.len() {
                let lapsed = matches!(
                    &cont.slots[i],
                    Some(s) if !s.done && deadline_passed(&s.req, s.submitted, now)
                );
                if lapsed {
                    let s = cont.release(i).unwrap();
                    expired.push(s.req.id);
                }
            }
        }
        for id in expired {
            self.timed_out += 1;
            self.token_watermark.remove(&id);
            self.push_event(ServeEvent::TimedOut { id });
        }
    }

    /// Contain a worker panic that unwound out of a prefill/decode call
    /// (surfaced as `Err` by `parallel::catch_panics`): every resident
    /// goes back to the queue *front* in admission order, the torn
    /// session is discarded (all paged blocks freed with it), and the
    /// next step rebuilds the pool and replays — per-request sampler
    /// streams make the replayed outputs bit-identical, exactly like
    /// preemption. The step reports no completions; nothing is lost.
    fn recover_from_panic(&mut self, err: anyhow::Error) {
        log::warn!("contained worker panic; replaying residents: {err:#}");
        self.panics_recovered += 1;
        if let Some(mut cont) = self.cont.take() {
            let mut residents: Vec<Slot> =
                cont.slots.iter_mut().filter_map(Option::take).collect();
            // youngest first, so the oldest ends up at the queue front
            residents.sort_by_key(|s| std::cmp::Reverse(s.join_seq));
            for s in residents {
                self.queue
                    .push_front(Pending { req: s.req, submitted: s.submitted });
            }
        }
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently resident in the continuous slot pool.
    pub fn in_flight(&self) -> usize {
        self.cont
            .as_ref()
            .map_or(0, |c| c.slots.iter().filter(|s| s.is_some()).count())
    }

    /// Ids of every request still owed a terminal state — queued
    /// entries first, then residents. The graceful-drain path uses
    /// this to cancel whatever is left once the drain timeout lapses.
    pub fn live_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.queue.iter().map(|p| p.req.id).collect();
        if let Some(cont) = &self.cont {
            ids.extend(cont.slots.iter().flatten().map(|s| s.req.id));
        }
        ids
    }

    /// Cap the serving batch (slot-pool size) — the knob `serve_bench`
    /// uses to grade both schedulers at one pool size. On the dense
    /// layout this clamps to the backend's maximum; with a paged pool
    /// slots are cheap handles whose real bound is the byte budget, so
    /// the cap may exceed [`NATIVE_MAX_BATCH`] (up to
    /// [`MAX_PAGED_SLOTS`]). Rejected while requests are in flight;
    /// resets the (empty) continuous pool so the next step rebuilds it.
    pub fn set_max_batch(&mut self, n: usize) -> Result<()> {
        ensure!(
            self.in_flight() == 0,
            "set_max_batch while {} requests are in flight",
            self.in_flight()
        );
        let cap = if self.kv.is_some() {
            MAX_PAGED_SLOTS
        } else {
            self.generator.max_batch()
        };
        self.max_batch = n.clamp(1, cap);
        self.cont = None;
        Ok(())
    }

    /// Switch the continuous slot pool onto the paged KV-cache
    /// subsystem (block tables + byte budget + prefix sharing; see
    /// DESIGN.md §KV-memory seam), or back to dense with `None`.
    /// Rejected while requests are in flight. Native KV engine only —
    /// enforced when the pool is built in [`Server::step`].
    pub fn set_kv_config(&mut self, kv: Option<KvCacheConfig>) -> Result<()> {
        ensure!(
            self.in_flight() == 0,
            "set_kv_config while {} requests are in flight",
            self.in_flight()
        );
        if let Some(kv) = &kv {
            // full geometry validation, not just field sanity: a byte
            // budget that cannot hold one context row would otherwise
            // zero-progress bail on every step (see kvcache.rs)
            crate::runtime::backend::kvcache::validate_budget(
                &self.generator.cfg,
                kv,
            )?;
        }
        self.kv = kv;
        // the dense slot cap may not apply anymore (and vice versa)
        self.max_batch = self.max_batch.clamp(
            1,
            if self.kv.is_some() {
                MAX_PAGED_SLOTS
            } else {
                self.generator.max_batch()
            },
        );
        self.cont = None;
        Ok(())
    }

    /// The active paged-KV configuration, if any.
    pub fn kv_config(&self) -> Option<&KvCacheConfig> {
        self.kv.as_ref()
    }

    /// Enable chunked prefill (`--prefill-chunk N`): prompt ingestion
    /// feeds at most `chunk` tokens per tick per row, interleaved with
    /// resident rows' decode steps, so a long arrival amortizes across
    /// ticks instead of stalling everyone's TPOT. `None` = monolithic
    /// prefill (the legacy path). Rejected while requests are in
    /// flight. Cache state after the last chunk is bit-identical to a
    /// monolithic prefill (dense always; paged under the f32 KV dtype
    /// — lossy dtypes quantize at chunk boundaries, the same caveat as
    /// the existing warm-prefix prefill).
    pub fn set_prefill_chunk(&mut self, chunk: Option<usize>) -> Result<()> {
        ensure!(
            self.in_flight() == 0,
            "set_prefill_chunk while {} requests are in flight",
            self.in_flight()
        );
        if let Some(c) = chunk {
            ensure!(c >= 1, "--prefill-chunk must be >= 1");
        }
        self.prefill_chunk = chunk;
        Ok(())
    }

    /// The active chunked-prefill size, if any.
    pub fn prefill_chunk(&self) -> Option<usize> {
        self.prefill_chunk
    }

    /// Enable self-speculative decoding (`--spec draft-k=K`) with the
    /// given draft model, or disable with `None`. The draft must share
    /// the target's vocabulary and have room for `draft_k` proposals
    /// plus one conditioning token in its context. Rejected while
    /// requests are in flight; the pool is rebuilt on the next step so
    /// the draft session comes up beside it.
    pub fn set_spec(
        &mut self,
        spec: Option<(SpecConfig, NativeModel)>,
    ) -> Result<()> {
        ensure!(
            self.in_flight() == 0,
            "set_spec while {} requests are in flight",
            self.in_flight()
        );
        match spec {
            Some((sc, model)) => {
                ensure!(sc.draft_k >= 1, "--spec draft-k must be >= 1");
                let dcfg = model.cfg.clone();
                ensure!(
                    dcfg.vocab == self.generator.cfg.vocab,
                    "draft vocab {} != target vocab {}",
                    dcfg.vocab,
                    self.generator.cfg.vocab
                );
                ensure!(
                    sc.draft_k + 1 <= dcfg.ctx,
                    "--spec draft-k={} does not fit the draft ctx {} \
                     (need draft-k + 1 <= ctx)",
                    sc.draft_k,
                    dcfg.ctx
                );
                self.spec = Some(sc);
                self.draft = Some(DraftState { model: Box::new(model), cfg: dcfg });
            }
            None => {
                self.spec = None;
                self.draft = None;
            }
        }
        self.cont = None;
        Ok(())
    }

    /// The active self-speculative decoding config, if any.
    pub fn spec_config(&self) -> Option<SpecConfig> {
        self.spec
    }

    /// Serving gauges: queue/pool occupancy and paged-KV block usage.
    pub fn stats(&self) -> ServeStats {
        let mut st = ServeStats {
            pending: self.pending(),
            in_flight: self.in_flight(),
            submitted: self.submitted,
            completed: self.completed,
            tokens_out: self.tokens_out,
            shed: self.shed,
            timed_out: self.timed_out,
            cancelled: self.cancelled,
            panics_recovered: self.panics_recovered,
            preemptions: self.preemptions,
            spec_proposed: self.spec_proposed,
            spec_accepted: self.spec_accepted,
            prefill_chunk_steps: self.prefill_chunk_steps,
            decode_steps: self.decode_steps,
            ..ServeStats::default()
        };
        if let Some(kv) = self.cont.as_ref().and_then(|c| c.sess.kv_stats()) {
            st.kv_paged = true;
            st.kv_total_blocks = kv.total_blocks;
            st.kv_free_blocks = kv.free_blocks;
            st.kv_shared_blocks = kv.shared_blocks;
            st.kv_block_tokens = kv.block_tokens;
        }
        st
    }

    /// Seal one request: build its response and record the per-request
    /// metrics (completion latency from `submit`, TTFT, TPOT).
    fn finish(&mut self, done: Done) -> GenResponse {
        let Done {
            id,
            tokens,
            text,
            prompt_tokens,
            submitted,
            first_token_at,
            batch_size,
            spec_proposed,
            spec_accepted,
        } = done;
        let now = Instant::now();
        let latency_ms = now.duration_since(submitted).as_secs_f64() * 1e3;
        let ttft_ms = first_token_at
            .map(|t| t.duration_since(submitted).as_secs_f64() * 1e3)
            .unwrap_or(latency_ms);
        let new_tokens = tokens.len();
        self.latencies.record_us(latency_ms * 1e3);
        self.ttft.record_us(ttft_ms * 1e3);
        // TPOT = decode-phase inter-token time: (completion - first
        // token) spans new_tokens - 1 decode steps, so a ≥2-token
        // request is needed for the ratio to mean anything. Recorded
        // only when the first token's time is known (continuous
        // scheduler); the static path records its own batch-wall rate.
        if first_token_at.is_some() && new_tokens > 1 {
            self.tpot
                .record_us((latency_ms - ttft_ms) * 1e3 / (new_tokens - 1) as f64);
        }
        self.completed += 1;
        self.tokens_out += new_tokens as u64;
        let resp = GenResponse {
            id,
            text: text.unwrap_or_else(|| ByteTokenizer.decode(&tokens)),
            new_tokens,
            tokens,
            prompt_tokens,
            latency_ms,
            ttft_ms,
            batch_size,
            spec_proposed,
            spec_accepted,
        };
        self.token_watermark.remove(&id);
        if self.events.is_some() {
            self.push_event(ServeEvent::Completed(resp.clone()));
        }
        resp
    }

    /// One tick of the **continuous-batching** scheduler (native KV
    /// engine only): admit queued requests into free slots (per-row
    /// prefill into the persistent session), advance every in-flight
    /// row by one token, and harvest finished rows — their slots free
    /// this same step, so the next tick's admissions take them.
    /// Returns the requests that completed this tick.
    pub fn step(&mut self) -> Result<Vec<GenResponse>> {
        ensure!(
            self.generator.supports_continuous(),
            "continuous batching needs the native KV decode engine \
             (this generator is {} / {}); use run_once/run_to_completion",
            self.generator.backend_name(),
            self.generator.decode_name()
        );
        if self.cont.is_none() {
            let sess = match &self.kv {
                Some(kv) => {
                    DecodeSession::new_paged(&self.generator.cfg, self.max_batch, kv)?
                }
                None => DecodeSession::new(&self.generator.cfg, self.max_batch),
            };
            // the draft session is always dense: the draft model is
            // tiny, its rows are short trailing windows, and rollback
            // past the accepted prefix must stay cheap
            let draft_sess = self
                .draft
                .as_ref()
                .map(|d| DecodeSession::new(&d.cfg, self.max_batch));
            self.cont = Some(ContState {
                sess,
                slots: (0..self.max_batch).map(|_| None).collect(),
                draft_sess,
            });
        }
        let vocab = self.generator.cfg.vocab;
        let mut out = Vec::new();

        // -- deadline sweep: requests whose deadline lapsed reach their
        //    terminal state (timed_out) before this tick admits or
        //    decodes anything — queued entries vanish from the queue,
        //    residents free their row and paged blocks mid-flight ------
        self.sweep_deadlines(Instant::now());

        // -- admission: requests join free rows mid-flight ---------------
        // Paged pools admit **by free blocks**: a joiner must fit its
        // whole-lifetime worst case (clamped prompt + budget - 1 cached
        // positions, at most one full row), and this tick's earlier
        // joiners hold reservations until their prefill lands.
        let mut joins: Vec<usize> = Vec::new();
        let mut reserved_blocks = 0usize;
        loop {
            let (max_new, prompt_bytes) = match self.queue.front() {
                Some(p) => (p.req.max_new_tokens, p.req.prompt.len()),
                None => break,
            };
            if max_new == 0 || prompt_bytes == 0 {
                // nothing to decode (zero budget), or nothing to attend
                // to (prompt clamps to empty): complete immediately, no
                // slot taken
                let p = self.queue.pop_front().unwrap();
                let prompt_tokens = if p.req.prompt.is_empty() {
                    0
                } else {
                    self.generator
                        .encode_prompts(std::slice::from_ref(&p.req.prompt), &[0])
                        .1[0]
                };
                let resp = self.finish(Done {
                    id: p.req.id,
                    tokens: Vec::new(),
                    text: Some(String::new()),
                    prompt_tokens,
                    submitted: p.submitted,
                    first_token_at: None,
                    batch_size: 1,
                    spec_proposed: 0,
                    spec_accepted: 0,
                });
                out.push(resp);
                continue;
            }
            let cont = self.cont.as_ref().unwrap();
            let Some(slot_idx) = cont.slots.iter().position(Option::is_none)
            else {
                break; // pool full; the queue waits for the next tick
            };
            if let Some(free) = cont.sess.kv_free_blocks() {
                // reserve the request's worst-case growth: its cache
                // peaks at clamped-prompt + budget - 1 positions
                // (ctx-capped), which never exceeds one full row — so a
                // lone request always fits and admission can never
                // live-lock. The byte tokenizer maps one byte to one
                // token, so the clamped prompt length is known without
                // encoding (no per-tick tokenize while blocked). The
                // reservation is tick-local; cross-tick overcommit is
                // what the preemption pass below resolves.
                let budget =
                    self.generator.cfg.ctx.saturating_sub(max_new).max(1);
                let ptoks = prompt_bytes.min(budget);
                let worst = ptoks + max_new.saturating_sub(1);
                let need = cont.sess.kv_blocks_for(worst).unwrap_or(0);
                if free < reserved_blocks + need {
                    break; // budget exhausted; wait (or preempt below)
                }
                reserved_blocks += need;
            }
            let p = self.queue.pop_front().unwrap();
            let (mut enc, ptoks) = self.generator.encode_prompts(
                std::slice::from_ref(&p.req.prompt),
                &[p.req.max_new_tokens],
            );
            let rng = Pcg32::new(self.generator.seed, p.req.id);
            self.next_join_seq += 1;
            let join_seq = self.next_join_seq;
            let cont = self.cont.as_mut().unwrap();
            cont.slots[slot_idx] = Some(Slot {
                prompt: enc.pop().unwrap(),
                prompt_tokens: ptoks[0],
                req: p.req,
                submitted: p.submitted,
                first_token_at: None,
                generated: Vec::new(),
                last: 0,
                done: false,
                rng,
                join_seq,
                phase: Phase::Prefill { fed: 0 },
                draft_cached: 0,
                spec_proposed: 0,
                spec_accepted: 0,
            });
            joins.push(slot_idx);
        }

        // -- prefill the joiners (parallel across joining rows) and,
        //    when their whole prompt landed, sample their first token
        //    from the prefill logits. Under chunked prefill only the
        //    first `--prefill-chunk` prompt tokens land here (through
        //    the same prefill_rows call, so paged prefix sharing still
        //    covers the first-chunk window); the rest feed one chunk
        //    per tick below, and the first token — hence TTFT — waits
        //    for the last chunk. ------------------------------------------
        if !joins.is_empty() {
            let chunk = self.prefill_chunk;
            let cont = self.cont.as_mut().unwrap();
            let mut pairs: Vec<(usize, &[i32])> =
                Vec::with_capacity(joins.len());
            for &i in &joins {
                let prompt = cont.slots[i].as_ref().unwrap().prompt.as_slice();
                let w = chunk.map_or(prompt.len(), |c| c.min(prompt.len()));
                pairs.push((i, &prompt[..w]));
            }
            // a worker panic inside the batched prefill is contained:
            // residents (joiners included) requeue and replay
            let prefilled = match &self.generator.exec {
                GenExec::Native { model, .. } => parallel::catch_panics(|| {
                    model.prefill_rows(&mut cont.sess, &pairs)
                }),
                #[cfg(feature = "pjrt")]
                GenExec::Pjrt { .. } => {
                    unreachable!("guarded by supports_continuous")
                }
            };
            let logits = match prefilled {
                Ok(r) => r?,
                Err(panic) => {
                    self.recover_from_panic(panic);
                    return Ok(out);
                }
            };
            let now = Instant::now();
            for (j, &slot_idx) in joins.iter().enumerate() {
                let slot = cont.slots[slot_idx].as_mut().unwrap();
                let plen = slot.prompt.len();
                let w = chunk.map_or(plen, |c| c.min(plen));
                if w < plen {
                    slot.phase = Phase::Prefill { fed: w };
                    continue; // prompt incomplete: no token yet
                }
                slot.phase = Phase::Decode;
                let row = &logits[j * vocab..(j + 1) * vocab];
                let tok = pick_token(row, slot.req.temperature, &mut slot.rng);
                let before = slot.generated.len();
                slot.feed(tok, now);
                if slot.generated.len() > before {
                    // exactly-once per position: replayed prefixes
                    // (preemption / panic recovery) are suppressed
                    emit_token_event(
                        &mut self.events,
                        &mut self.token_watermark,
                        slot.req.id,
                        slot.generated.len(),
                        tok,
                    );
                }
            }
            if chunk.is_some() {
                self.prefill_chunk_steps += joins.len() as u64;
            }
        }

        // -- paged memory pressure: whole-request preempt-and-requeue ----
        // The decode step below never allocation-fails: while the pool
        // cannot cover the step's worst-case block demand, the youngest
        // resident request is evicted, its blocks are freed, and the
        // request goes back to the *front* of the queue. Per-request
        // sampler streams make the replay emit identical tokens, so
        // preemption is invisible in outputs — only in latency.
        if self.cont.as_ref().unwrap().sess.is_paged() {
            loop {
                let cont = self.cont.as_ref().unwrap();
                // price the whole tick, not just the decode step: a
                // prompt-chunk continuation needs its chunk (plus the
                // first decode token when the chunk completes the
                // prompt), and a speculative round extends the target
                // by k proposals + 1 conditioning token before rolling
                // back — the extensions below must never alloc-fail
                let plans = plan_rows(
                    cont,
                    &joins,
                    self.prefill_chunk,
                    self.spec,
                    self.generator.cfg.ctx,
                );
                let active: Vec<bool> = plans
                    .iter()
                    .map(|p| matches!(p, Some(RowPlan::Decode)))
                    .collect();
                let mut demand = cont.sess.paged_step_demand(&active);
                for (i, p) in plans.iter().enumerate() {
                    match p {
                        Some(RowPlan::Chunk { len, completes }) => {
                            demand += cont
                                .sess
                                .paged_extend_demand(i, len + usize::from(*completes));
                        }
                        Some(RowPlan::Spec { k }) => {
                            demand += cont.sess.paged_extend_demand(i, k + 1);
                        }
                        _ => {}
                    }
                }
                if cont.sess.kv_free_blocks().unwrap_or(0) >= demand {
                    break;
                }
                // victim = youngest still-decoding resident, as long as
                // at least one other decoding row survives; rows that
                // finished this tick (harvested below) are evicted only
                // as a last resort — their completed tokens would be
                // thrown away and deterministically recomputed.
                let (mut live, mut done): (Option<(usize, u64)>, Option<(usize, u64)>) =
                    (None, None);
                let mut live_count = 0usize;
                for (i, s) in cont.slots.iter().enumerate() {
                    let Some(s) = s else { continue };
                    let best = if s.done { &mut done } else { &mut live };
                    if !s.done {
                        live_count += 1;
                    }
                    if best.map_or(true, |(_, seq)| s.join_seq > seq) {
                        *best = Some((i, s.join_seq));
                    }
                }
                let victim = if live_count > 1 {
                    live.map(|(i, _)| i)
                } else {
                    done.map(|(i, _)| i)
                };
                let Some(victim) = victim else {
                    bail!(
                        "kv pool cannot cover a single request's step; \
                         raise --kv-mem-mb or shrink --kv-block"
                    );
                };
                let cont = self.cont.as_mut().unwrap();
                let slot = cont.release(victim).unwrap();
                joins.retain(|&i| i != victim);
                self.preemptions += 1;
                self.queue
                    .push_front(Pending { req: slot.req, submitted: slot.submitted });
            }
        }

        // -- this tick's per-row plan, recomputed once more now that
        //    the preemption pass has settled (nothing below releases a
        //    row, so the plan is stable through execution) ---------------
        let plans = {
            let cont = self.cont.as_ref().unwrap();
            plan_rows(
                cont,
                &joins,
                self.prefill_chunk,
                self.spec,
                self.generator.cfg.ctx,
            )
        };

        // -- chunked-prefill continuation: one chunk per row per tick,
        //    batched across rows through the multi-position extension.
        //    A completing chunk samples the row's first token (this is
        //    where TTFT starts under chunking) and the row joins this
        //    same tick's decode step below. --------------------------------
        let chunk_rows: Vec<(usize, usize, bool)> = plans
            .iter()
            .enumerate()
            .filter_map(|(i, p)| match p {
                Some(RowPlan::Chunk { len, completes }) => {
                    Some((i, *len, *completes))
                }
                _ => None,
            })
            .collect();
        if !chunk_rows.is_empty() {
            let ContState { sess, slots, .. } = self.cont.as_mut().unwrap();
            let reqs: Vec<ExtendReq<'_>> = chunk_rows
                .iter()
                .map(|&(i, len, completes)| {
                    let s = slots[i].as_ref().unwrap();
                    let Phase::Prefill { fed } = s.phase else {
                        unreachable!("Chunk plan on a non-prefill row")
                    };
                    ExtendReq {
                        slot: i,
                        tokens: &s.prompt[fed..fed + len],
                        logits: if completes {
                            ExtendLogits::Last
                        } else {
                            ExtendLogits::None
                        },
                    }
                })
                .collect();
            let extended = match &self.generator.exec {
                GenExec::Native { model, .. } => parallel::catch_panics(|| {
                    model.extend_rows(sess, &reqs)
                }),
                #[cfg(feature = "pjrt")]
                GenExec::Pjrt { .. } => {
                    unreachable!("guarded by supports_continuous")
                }
            };
            let logit_rows = match extended {
                Ok(r) => r?,
                Err(panic) => {
                    self.recover_from_panic(panic);
                    return Ok(out);
                }
            };
            let now = Instant::now();
            for (&(i, len, completes), lrow) in
                chunk_rows.iter().zip(logit_rows.iter())
            {
                let slot = slots[i].as_mut().unwrap();
                let Phase::Prefill { fed } = slot.phase else {
                    unreachable!()
                };
                if completes {
                    slot.phase = Phase::Decode;
                    let tok =
                        pick_token(lrow, slot.req.temperature, &mut slot.rng);
                    let before = slot.generated.len();
                    slot.feed(tok, now);
                    if slot.generated.len() > before {
                        emit_token_event(
                            &mut self.events,
                            &mut self.token_watermark,
                            slot.req.id,
                            slot.generated.len(),
                            tok,
                        );
                    }
                } else {
                    slot.phase = Phase::Prefill { fed: fed + len };
                }
            }
            self.prefill_chunk_steps += chunk_rows.len() as u64;
        }

        // -- one decode step across whatever mix of in-flight rows
        //    exists (rows running a speculative round this tick sit it
        //    out; rows whose last prompt chunk just landed join in) ------
        let spec_planned: Vec<bool> = plans
            .iter()
            .map(|p| matches!(p, Some(RowPlan::Spec { .. })))
            .collect();
        {
            let cont = self.cont.as_mut().unwrap();
            let b = cont.slots.len();
            let mut active = vec![false; b];
            let mut last = vec![0i32; b];
            for (i, s) in cont.slots.iter().enumerate() {
                if let Some(s) = s {
                    if !s.done && s.phase == Phase::Decode && !spec_planned[i] {
                        active[i] = true;
                        last[i] = s.last;
                    }
                }
            }
            if active.iter().any(|&a| a) {
                self.decode_steps += 1;
                // worker panics are contained here too: the torn step's
                // residents requeue and replay deterministically
                let stepped = match &self.generator.exec {
                    GenExec::Native { model, .. } => parallel::catch_panics(|| {
                        model.decode_step_active(&mut cont.sess, &last, &active)
                    }),
                    #[cfg(feature = "pjrt")]
                    GenExec::Pjrt { .. } => {
                        unreachable!("guarded by supports_continuous")
                    }
                };
                let logits = match stepped {
                    Ok(r) => r?,
                    Err(panic) => {
                        self.recover_from_panic(panic);
                        return Ok(out);
                    }
                };
                let now = Instant::now();
                for i in 0..b {
                    if !active[i] {
                        continue;
                    }
                    let slot = cont.slots[i].as_mut().unwrap();
                    let row = &logits[i * vocab..(i + 1) * vocab];
                    let tok =
                        pick_token(row, slot.req.temperature, &mut slot.rng);
                    let before = slot.generated.len();
                    slot.feed(tok, now);
                    if slot.generated.len() > before {
                        emit_token_event(
                            &mut self.events,
                            &mut self.token_watermark,
                            slot.req.id,
                            slot.generated.len(),
                            tok,
                        );
                    }
                }
            }
        }

        // -- speculative rounds: the draft model proposes k greedy
        //    tokens per planned row (k batched draft steps), one batched
        //    target extension scores every proposal at once, and the
        //    longest matched prefix plus the target's own bonus token is
        //    accepted. Both KV rows then roll back past the accepted
        //    prefix. Greedy acceptance makes the emitted stream
        //    bit-identical to plain one-token-per-step decode. -----------
        let spec_rows: Vec<(usize, usize)> = plans
            .iter()
            .enumerate()
            .filter_map(|(i, p)| match p {
                Some(RowPlan::Spec { k }) => Some((i, *k)),
                _ => None,
            })
            .collect();
        if !spec_rows.is_empty() {
            let draft =
                self.draft.as_ref().expect("Spec plan without a draft model");
            let dctx = draft.cfg.ctx;
            let dvocab = draft.cfg.vocab;
            let ContState { sess, slots, draft_sess } =
                self.cont.as_mut().unwrap();
            let dsess = draft_sess
                .as_mut()
                .expect("Spec plan without a draft session");
            let b = slots.len();

            // sync each draft row so it caches (a trailing window of)
            // the committed tokens minus the pending last one: cold rows
            // and rows whose k proposals would overrun the draft ctx
            // re-prefill a window; rows that fell behind (plain decode
            // steps ran in between, or a fully-accepted round left its
            // final proposal unfed) extend with the missing tokens
            let mut reprefill: Vec<(usize, Vec<i32>, usize)> = Vec::new();
            let mut gap_feed: Vec<(usize, Vec<i32>)> = Vec::new();
            for &(i, k) in &spec_rows {
                let s = slots[i].as_ref().unwrap();
                let committed = s.prompt.len() + s.generated.len();
                let need = committed - 1;
                let have = s.draft_cached;
                let dlen = dsess.len_of(i);
                let gap = need.saturating_sub(have);
                if have == 0 || have > need || dlen + gap + k > dctx {
                    let w = need.min(dctx - k);
                    let window: Vec<i32> =
                        (need - w..need).map(|j| committed_token(s, j)).collect();
                    reprefill.push((i, window, need));
                } else if gap > 0 {
                    let fill: Vec<i32> =
                        (have..need).map(|j| committed_token(s, j)).collect();
                    gap_feed.push((i, fill));
                }
            }
            if !reprefill.is_empty() {
                let pairs: Vec<(usize, &[i32])> = reprefill
                    .iter()
                    .map(|(i, w, _)| (*i, w.as_slice()))
                    .collect();
                match parallel::catch_panics(|| {
                    draft.model.prefill_rows(dsess, &pairs)
                }) {
                    Ok(r) => {
                        r?;
                    }
                    Err(panic) => {
                        self.recover_from_panic(panic);
                        return Ok(out);
                    }
                }
                for (i, _, need) in &reprefill {
                    slots[*i].as_mut().unwrap().draft_cached = *need;
                }
            }
            if !gap_feed.is_empty() {
                let reqs: Vec<ExtendReq<'_>> = gap_feed
                    .iter()
                    .map(|(i, toks)| ExtendReq {
                        slot: *i,
                        tokens: toks,
                        logits: ExtendLogits::None,
                    })
                    .collect();
                match parallel::catch_panics(|| {
                    draft.model.extend_rows(dsess, &reqs)
                }) {
                    Ok(r) => {
                        r?;
                    }
                    Err(panic) => {
                        self.recover_from_panic(panic);
                        return Ok(out);
                    }
                }
                for (i, toks) in &gap_feed {
                    slots[*i].as_mut().unwrap().draft_cached += toks.len();
                }
            }

            // k batched greedy draft steps propose the continuation
            let kmax = spec_rows.iter().map(|&(_, k)| k).max().unwrap();
            let mut proposals: Vec<Vec<i32>> = vec![Vec::new(); b];
            let mut feed = vec![0i32; b];
            let mut dlen0 = vec![0usize; b];
            for &(i, _) in &spec_rows {
                feed[i] = slots[i].as_ref().unwrap().last;
                dlen0[i] = dsess.len_of(i);
            }
            for t in 0..kmax {
                let mut active = vec![false; b];
                for &(i, k) in &spec_rows {
                    if t < k {
                        active[i] = true;
                    }
                }
                let stepped = parallel::catch_panics(|| {
                    draft.model.decode_step_active(dsess, &feed, &active)
                });
                let logits = match stepped {
                    Ok(r) => r?,
                    Err(panic) => {
                        self.recover_from_panic(panic);
                        return Ok(out);
                    }
                };
                for &(i, k) in &spec_rows {
                    if t >= k {
                        continue;
                    }
                    let row = &logits[i * dvocab..(i + 1) * dvocab];
                    let p = argmax(row) as i32;
                    proposals[i].push(p);
                    feed[i] = p;
                }
            }

            // one batched target extension scores every proposal: row m
            // of a request's returned logits is the target's next-token
            // distribution after [last, p1..pm]
            let verify_toks: Vec<(usize, Vec<i32>)> = spec_rows
                .iter()
                .map(|&(i, _)| {
                    let mut t = Vec::with_capacity(proposals[i].len() + 1);
                    t.push(slots[i].as_ref().unwrap().last);
                    t.extend_from_slice(&proposals[i]);
                    (i, t)
                })
                .collect();
            let len0: Vec<usize> =
                spec_rows.iter().map(|&(i, _)| sess.len_of(i)).collect();
            let reqs: Vec<ExtendReq<'_>> = verify_toks
                .iter()
                .map(|(i, t)| ExtendReq {
                    slot: *i,
                    tokens: t,
                    logits: ExtendLogits::All,
                })
                .collect();
            let verified = match &self.generator.exec {
                GenExec::Native { model, .. } => parallel::catch_panics(|| {
                    model.extend_rows(sess, &reqs)
                }),
                #[cfg(feature = "pjrt")]
                GenExec::Pjrt { .. } => {
                    unreachable!("guarded by supports_continuous")
                }
            };
            let all_logits = match verified {
                Ok(r) => r?,
                Err(panic) => {
                    self.recover_from_panic(panic);
                    return Ok(out);
                }
            };
            let now = Instant::now();
            for (idx, (i, toks)) in verify_toks.iter().enumerate() {
                let lrows = &all_logits[idx];
                let k = toks.len() - 1;
                let slot = slots[*i].as_mut().unwrap();
                let committed = slot.prompt.len() + slot.generated.len();
                // acceptance walk: a proposal matching the target's
                // argmax commits and moves the walk forward; the first
                // mismatch (or running out of proposals) makes that
                // argmax the bonus token — always ≥1 emitted token, so
                // a round never regresses below plain decode
                let mut m = 0usize;
                let mut emitted = Vec::with_capacity(k + 1);
                loop {
                    let t = argmax(&lrows[m * vocab..(m + 1) * vocab]) as i32;
                    emitted.push(t);
                    if m < k && toks[m + 1] == t {
                        m += 1;
                    } else {
                        break;
                    }
                }
                slot.spec_proposed += k as u64;
                slot.spec_accepted += m as u64;
                self.spec_proposed += k as u64;
                self.spec_accepted += m as u64;
                for &t in &emitted {
                    if slot.done {
                        break; // a stop token ended the request mid-walk
                    }
                    let before = slot.generated.len();
                    slot.feed(t, now);
                    if slot.generated.len() > before {
                        emit_token_event(
                            &mut self.events,
                            &mut self.token_watermark,
                            slot.req.id,
                            slot.generated.len(),
                            t,
                        );
                    }
                }
                // roll both KV rows back past the accepted prefix: the
                // verify extension fed 1 + k tokens of which 1 + m are
                // committed; the draft fed [last, p1..p_{k-1}] of which
                // 1 + min(m, k - 1) are
                sess.rollback_row(*i, len0[idx] + 1 + m);
                let dl = dsess.len_of(*i);
                dsess.rollback_row(*i, (dlen0[*i] + 1 + m).min(dl));
                slot.draft_cached = if m < k {
                    committed + m
                } else {
                    committed + k - 1
                };
            }
        }

        // -- harvest: finished rows free their slot this same step -------
        let occupancy = self.in_flight();
        let mut finished: Vec<Slot> = Vec::new();
        {
            let cont = self.cont.as_mut().unwrap();
            for i in 0..cont.slots.len() {
                if matches!(&cont.slots[i], Some(s) if s.done) {
                    finished.push(cont.release(i).unwrap());
                }
            }
        }
        for slot in finished {
            let resp = self.finish(Done {
                id: slot.req.id,
                tokens: slot.generated,
                text: None,
                prompt_tokens: slot.prompt_tokens,
                submitted: slot.submitted,
                first_token_at: slot.first_token_at,
                batch_size: occupancy,
                spec_proposed: slot.spec_proposed,
                spec_accepted: slot.spec_accepted,
            });
            out.push(resp);
        }
        Ok(out)
    }

    /// Drain the queue and the in-flight pool with the continuous
    /// scheduler (arrival-free convenience wrapper; real-time callers
    /// drive [`Server::step`] from their own event loop so arrivals can
    /// join mid-flight).
    pub fn run_continuous(&mut self) -> Result<Vec<GenResponse>> {
        let mut all = Vec::new();
        while self.pending() > 0 || self.in_flight() > 0 {
            all.extend(self.step()?);
        }
        Ok(all)
    }

    /// Serve one **static** batch from the queue (up to the slot cap),
    /// draining it to completion; returns the completed responses.
    /// No-op on an empty queue. This is the vLLM-v0-style reference
    /// scheduler: a 2-token request co-batched with a 64-token one
    /// waits for the whole drain, which is exactly the head-of-line
    /// blocking [`Server::step`] removes — kept because its greedy
    /// per-request outputs are provably identical to the continuous
    /// scheduler's, and because the PJRT backend is lock-step.
    ///
    /// Every request keeps its own temperature, `max_new_tokens` and
    /// stop token; accounting is in token space and per request
    /// (`latency_ms` runs from that request's `submit` to the batch
    /// completing — queue wait included).
    pub fn run_once(&mut self) -> Result<Vec<GenResponse>> {
        if self.queue.is_empty() {
            return Ok(Vec::new());
        }
        // requests resident in the continuous pool would be silently
        // stranded (they complete only through step()): refuse to mix
        ensure!(
            self.in_flight() == 0,
            "run_once while {} requests are in flight on the continuous \
             scheduler; drain them with step()/run_continuous() first",
            self.in_flight()
        );
        // empty prompts (nothing to attend to after clamping) complete
        // immediately and never occupy a batch slot — mirroring the
        // continuous scheduler's admission path, so the two schedulers
        // stay response-equivalent on degenerate requests
        let mut out = Vec::new();
        let cap = self.max_batch.min(self.generator.max_batch());
        let mut batch: Vec<Pending> = Vec::new();
        while batch.len() < cap {
            let Some(p) = self.queue.pop_front() else { break };
            if p.req.prompt.is_empty() {
                let resp = self.finish(Done {
                    id: p.req.id,
                    tokens: Vec::new(),
                    text: Some(String::new()),
                    prompt_tokens: 0,
                    submitted: p.submitted,
                    first_token_at: None,
                    batch_size: 1,
                    spec_proposed: 0,
                    spec_accepted: 0,
                });
                out.push(resp);
                continue;
            }
            batch.push(p);
        }
        if batch.is_empty() {
            return Ok(out);
        }
        let b = batch.len();
        let prompts: Vec<String> =
            batch.iter().map(|p| p.req.prompt.clone()).collect();
        let max_new: Vec<usize> =
            batch.iter().map(|p| p.req.max_new_tokens).collect();
        let temps: Vec<f32> = batch.iter().map(|p| p.req.temperature).collect();

        let t0 = Instant::now();
        let gen = self.generator.generate_batch_ext(&prompts, &max_new, &temps)?;
        let dt_ms = t0.elapsed().as_secs_f64() * 1e3;

        out.reserve(b);
        // the batch emitted one token per row per sampling step, so the
        // honest static TPOT is wall time over the *steps the batch
        // ran* (the deepest row), not over any single row's own count —
        // a 2-token row next to a 64-token one experienced the same
        // per-token cadence as its neighbor
        let steps = gen.tokens.iter().map(Vec::len).max().unwrap_or(0);
        let rows = batch
            .into_iter()
            .zip(gen.tokens)
            .zip(gen.texts)
            .zip(gen.prompt_tokens);
        for (((p, mut toks), row_text), prompt_tokens) in rows {
            if !toks.is_empty() {
                self.tpot.record_us(dt_ms * 1e3 / steps as f64);
            }
            let mut text = Some(row_text);
            // optional stop token: truncate at its first occurrence —
            // the same sequence the continuous scheduler stops at (it
            // just never generates the tail in the first place)
            if let Some(stop) = p.req.stop {
                if let Some(cut) = toks.iter().position(|&t| t == stop) {
                    toks.truncate(cut);
                    // the byte decode is lossy, so the pre-truncation
                    // string cannot simply be sliced — recompute
                    text = None;
                }
            }
            let resp = self.finish(Done {
                id: p.req.id,
                tokens: toks,
                text, // truncation dropped it; finish re-decodes then
                prompt_tokens,
                submitted: p.submitted,
                // static batching streams nothing early: TTFT = latency
                first_token_at: None,
                batch_size: b,
                spec_proposed: 0,
                spec_accepted: 0,
            });
            out.push(resp);
        }
        Ok(out)
    }

    /// Drain the whole queue with the static scheduler.
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResponse>> {
        let mut all = Vec::new();
        while !self.queue.is_empty() {
            all.extend(self.run_once()?);
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_finds_max() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 3.0]), 1); // first max wins
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn temperature_sampling_respects_distribution() {
        let mut rng = Pcg32::seeded(0);
        let logits = vec![0.0f32, 5.0, 0.0, 0.0];
        let mut hits = 0;
        for _ in 0..200 {
            if sample_temperature(&logits, 1.0, &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 180, "{hits}");
    }

    #[test]
    fn high_temperature_flattens() {
        let mut rng = Pcg32::seeded(1);
        let logits = vec![0.0f32, 5.0, 0.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[sample_temperature(&logits, 50.0, &mut rng)] += 1;
        }
        // near uniform at T=50
        for c in counts {
            assert!(c > 300, "{counts:?}");
        }
    }

    #[test]
    fn degenerate_logit_rows_fall_back_to_greedy() {
        // pre-fix, each of these panicked inside Pcg32::weighted
        // ("weights must have positive mass") and took the server down
        let mut rng = Pcg32::seeded(2);
        assert_eq!(pick_token(&[f32::NEG_INFINITY; 4], 0.7, &mut rng), 0);
        let t = pick_token(&[f32::NAN; 4], 0.7, &mut rng);
        assert!((0..4).contains(&(t as usize)));
        // +inf spike: greedy fallback picks the spike deterministically,
        // even past a NaN incumbent at index 0
        assert_eq!(pick_token(&[0.0, f32::INFINITY, 0.0], 1.0, &mut rng), 1);
        assert_eq!(pick_token(&[f32::NAN, f32::INFINITY], 1.0, &mut rng), 1);
        // tiny temperature: every non-max weight underflows to zero but
        // the max keeps unit mass — sampling must stay on the argmax
        assert_eq!(pick_token(&[0.0, 100.0, -50.0], 1e-30, &mut rng), 1);
        // mixed row: -inf entries carry no mass, finite ones still sample
        for _ in 0..50 {
            let t =
                pick_token(&[f32::NEG_INFINITY, 3.0, f32::NEG_INFINITY], 0.8, &mut rng);
            assert_eq!(t, 1);
        }
    }

    #[test]
    fn decode_mode_parses() {
        assert_eq!(DecodeMode::parse("kv").unwrap(), DecodeMode::Kv);
        assert_eq!(
            DecodeMode::parse("recompute").unwrap(),
            DecodeMode::Recompute
        );
        assert!(DecodeMode::parse("flash").is_err());
        assert_eq!(DecodeMode::Kv.name(), "kv");
    }

    fn native_generator() -> Generator<'static> {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let store = ParamStore::init(&cfg, 5).unwrap();
        Generator::native(&cfg, &store, 0).unwrap()
    }

    fn recompute_generator() -> Generator<'static> {
        let cfg = ModelConfig::builtin("tiny", "consmax").unwrap();
        let store = ParamStore::init(&cfg, 5).unwrap();
        Generator::native_with(&cfg, &store, 0, DecodeMode::Recompute).unwrap()
    }

    #[test]
    fn native_greedy_generation_is_deterministic() {
        let mut g1 = native_generator();
        let mut g2 = native_generator();
        let a = g1.generate_batch(&["hello ".into()], 8, 0.0).unwrap();
        let b = g2.generate_batch(&["hello ".into()], 8, 0.0).unwrap();
        assert_eq!(a, b);
        assert_eq!(a[0].len(), 8);
        assert_eq!(g1.backend_name(), "native");
        assert_eq!(g1.decode_name(), "kv");
        assert!(g1.supports_continuous());
    }

    #[test]
    fn kv_and_recompute_greedy_agree() {
        let mut kv = native_generator();
        let mut rc = recompute_generator();
        let a = kv.generate_batch(&["hello ".into()], 10, 0.0).unwrap();
        let b = rc.generate_batch(&["hello ".into()], 10, 0.0).unwrap();
        assert_eq!(a, b);
        assert_eq!(rc.decode_name(), "recompute");
        assert!(!rc.supports_continuous());
    }

    #[test]
    fn native_generation_respects_context_budget() {
        let mut g = native_generator();
        let long = "x".repeat(g.cfg.ctx * 2);
        let out = g.generate_batch(&[long], 6, 0.0).unwrap();
        assert_eq!(out[0].len(), 6);
    }

    #[test]
    fn prompt_tokens_report_post_clamp_length() {
        let mut g = native_generator();
        // multi-byte UTF-8: 5 chars but 7 bytes => 7 byte-tokens
        let out = g
            .generate_batch_ext(&["héllö".into()], &[3], &[0.0])
            .unwrap();
        assert_eq!(out.prompt_tokens, vec![7]);
        assert_eq!(out.tokens[0].len(), 3);

        // over-long prompt clamps to ctx - max_new
        let long = "y".repeat(g.cfg.ctx * 3);
        let out = g.generate_batch_ext(&[long], &[4], &[0.0]).unwrap();
        assert_eq!(out.prompt_tokens, vec![g.cfg.ctx - 4]);
    }

    #[test]
    fn native_server_serves_all_requests() {
        let mut server = Server::new(native_generator());
        for id in 0..3 {
            server.submit(GenRequest::greedy(id, format!("prompt {id} "), 4));
        }
        let responses = server.run_to_completion().unwrap();
        assert_eq!(responses.len(), 3);
        assert_eq!(server.pending(), 0);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);
        for r in &responses {
            assert_eq!(r.new_tokens, 4);
            assert!(r.latency_ms > 0.0);
            assert!(r.ttft_ms > 0.0 && r.ttft_ms <= r.latency_ms);
        }
        assert_eq!(server.latencies.len(), 3);
        assert_eq!(server.ttft.len(), 3);
        assert_eq!(server.tokens_out, 12); // token-space accounting
    }

    #[test]
    fn per_request_budgets_are_respected() {
        let mut server = Server::new(native_generator());
        for (id, max_new) in [(0u64, 2usize), (1, 7), (2, 4)] {
            server.submit(GenRequest::greedy(id, "shared prompt ", max_new));
        }
        let mut responses = server.run_to_completion().unwrap();
        responses.sort_by_key(|r| r.id);
        let counts: Vec<usize> = responses.iter().map(|r| r.new_tokens).collect();
        assert_eq!(counts, vec![2, 7, 4]);
        assert_eq!(server.tokens_out, 13);
    }

    #[test]
    fn continuous_scheduler_serves_the_queue() {
        // smoke-level: the full equivalence suite lives in
        // rust/tests/continuous_batching.rs
        let mut server = Server::new(native_generator());
        for id in 0..5 {
            server.submit(GenRequest::greedy(id, format!("req {id} "), 2 + id as usize));
        }
        let responses = server.run_continuous().unwrap();
        assert_eq!(responses.len(), 5);
        assert_eq!(server.pending(), 0);
        assert_eq!(server.in_flight(), 0);
        assert_eq!(server.tokens_out, (2 + 3 + 4 + 5 + 6) as u64);
        for r in &responses {
            assert_eq!(r.new_tokens, 2 + r.id as usize);
            assert_eq!(r.tokens.len(), r.new_tokens);
            assert!(r.ttft_ms <= r.latency_ms);
        }
    }

    #[test]
    fn continuous_rejected_off_the_kv_engine() {
        let mut server = Server::new(recompute_generator());
        server.submit(GenRequest::greedy(0, "p", 2));
        assert!(server.step().is_err());
        // the static oracle still serves it
        let responses = server.run_to_completion().unwrap();
        assert_eq!(responses.len(), 1);
    }

    #[test]
    fn set_max_batch_caps_both_schedulers() {
        let mut server = Server::new(native_generator());
        server.set_max_batch(2).unwrap();
        for id in 0..5 {
            server.submit(GenRequest::greedy(id, "x ", 2));
        }
        let first = server.run_once().unwrap();
        assert_eq!(first.len(), 2);
        assert!(first.iter().all(|r| r.batch_size == 2));
        let rest = server.run_continuous().unwrap();
        assert_eq!(rest.len(), 3);
        assert!(rest.iter().all(|r| r.batch_size <= 2));
        // live pool blocks resizing; empty pool allows it
        assert!(server.set_max_batch(4).is_ok());
    }

    #[test]
    fn oversize_batch_rejected() {
        let mut g = native_generator();
        let prompts: Vec<String> =
            (0..NATIVE_MAX_BATCH + 1).map(|i| format!("p{i}")).collect();
        assert!(g.generate_batch(&prompts, 2, 0.0).is_err());
    }

    fn degenerate_reqs() -> Vec<GenRequest> {
        vec![
            // empty prompt clamps to empty: complete-and-skip
            GenRequest::greedy(0, "", 5),
            GenRequest::greedy(1, "real ", 3),
        ]
    }

    #[test]
    fn empty_prompts_complete_and_skip_on_both_schedulers() {
        for continuous in [true, false] {
            let mut server = Server::new(native_generator());
            for req in degenerate_reqs() {
                server.submit(req);
            }
            let mut rs = if continuous {
                server.run_continuous().unwrap()
            } else {
                server.run_to_completion().unwrap()
            };
            rs.sort_by_key(|r| r.id);
            assert_eq!(rs.len(), 2, "continuous={continuous}");
            assert_eq!(rs[0].new_tokens, 0, "continuous={continuous}");
            assert_eq!(rs[0].text, "");
            assert_eq!(rs[0].prompt_tokens, 0);
            assert_eq!(rs[1].new_tokens, 3, "continuous={continuous}");
            assert_eq!(server.tokens_out, 3);
        }
    }

    #[test]
    fn paged_pool_serves_and_reports_stats() {
        use crate::config::KvCacheConfig;
        let mut server = Server::new(native_generator());
        server
            .set_kv_config(Some(KvCacheConfig {
                block_tokens: 8,
                ..KvCacheConfig::default()
            }))
            .unwrap();
        server.set_max_batch(4).unwrap();
        for id in 0..6u64 {
            server.submit(GenRequest::greedy(id, "one shared prefix prompt ", 3));
        }
        let rs = server.run_continuous().unwrap();
        assert_eq!(rs.len(), 6);
        for r in &rs {
            assert_eq!(r.new_tokens, 3);
        }
        let st = server.stats();
        assert!(st.kv_paged);
        assert!(st.kv_total_blocks > 0);
        assert_eq!(st.kv_block_tokens, 8);
        // every row finished: all block references returned to the pool
        assert_eq!(st.kv_free_blocks, st.kv_total_blocks);
        assert_eq!(st.in_flight, 0);
        assert_eq!(st.completed, 6);
    }

    #[test]
    fn kv_config_rejected_mid_flight_and_paged_slots_exceed_dense_cap() {
        use crate::config::KvCacheConfig;
        let mut server = Server::new(native_generator());
        // paged pools may raise the slot cap past the dense engine max
        server.set_kv_config(Some(KvCacheConfig::default())).unwrap();
        server.set_max_batch(NATIVE_MAX_BATCH * 2).unwrap();
        server.submit(GenRequest::greedy(0, "p ", 4));
        server.step().unwrap();
        assert_eq!(server.in_flight(), 1);
        assert!(server.set_kv_config(None).is_err());
        server.run_continuous().unwrap();
        assert!(server.set_kv_config(None).is_ok());
        // back on dense: the cap clamps to the engine max again
        server.set_max_batch(NATIVE_MAX_BATCH * 2).unwrap();
        server.submit(GenRequest::greedy(1, "q ", 2));
        let rs = server.run_continuous().unwrap();
        assert_eq!(rs.len(), 1);
    }
}
