//! The attention-pipeline model.
//!
//! Units of time are clock cycles. Per score element, the QK module needs
//! `ceil(head_dim / qk_lanes)` cycles (dot product of a d-wide query row
//! with one key vector) and the PV module `ceil(head_dim / pv_lanes)`
//! cycles (rank-1 update of the d-wide output accumulator). The
//! normalizer's behaviour is what distinguishes the designs:
//!
//! * `Softmax`: running max tracks arrivals (free), but exp/sum needs the
//!   *final* max, so a second full pass over the buffered vector runs
//!   after the last score arrives; emission (with the divide) follows the
//!   pass at 1 element/cycle.
//! * `Softermax`: online base-2 renormalization folds the sum pass into
//!   arrival (multiplying the running sum by 2^(m_old−m_new)), so emission
//!   starts right after the last score arrives (reciprocal ready); still a
//!   per-token barrier.
//! * `PartialSoftmax{chunks}`: FlashAttention-style — each chunk is
//!   softmaxed locally as it completes, but emission still waits for the
//!   global synchronization at the end (local sums/maxes merged, then a
//!   rescale pass at 1 elem/cycle).
//! * `ConSmax`: pure streaming — each score is normalized `lat` cycles
//!   after it arrives, no barrier at all.

/// Normalizer behaviour in the pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormKind {
    Softmax,
    Softermax,
    PartialSoftmax { chunks: usize },
    ConSmax,
}

impl NormKind {
    pub fn name(self) -> String {
        match self {
            NormKind::Softmax => "Softmax".into(),
            NormKind::Softermax => "Softermax".into(),
            NormKind::PartialSoftmax { chunks } => format!("PartialSoftmax/{chunks}"),
            NormKind::ConSmax => "ConSmax".into(),
        }
    }

    /// Whether the normalizer permits the element-wise schedule.
    pub fn is_streaming(self) -> bool {
        matches!(self, NormKind::ConSmax)
    }
}

/// Dataflow schedule of the accelerator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Coarse-grained: modules hand off whole tokens (Fig 2).
    TokenPipeline,
    /// Fine-grained: normalized elements stream into PV (Fig 4b).
    /// Requires a streaming normalizer (ConSmax).
    ElementWise,
}

/// Workload description.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    /// Tokens to process (1 = generation step; >1 = summarization).
    pub tokens: usize,
    /// Score-vector length per token (context size).
    pub seq: usize,
    /// Head dimension (dot-product length).
    pub head_dim: usize,
    /// MAC lanes in the QK tensor core.
    pub qk_lanes: usize,
    /// MAC lanes in the PV tensor core.
    pub pv_lanes: usize,
    /// Normalizer pipeline latency (fill cycles from input to output).
    pub norm_latency: u64,
}

impl Workload {
    /// The paper's evaluation point: 256-token context, head_dim 64
    /// (GPT-2 small heads), matched 64-lane tensor cores.
    pub fn paper_generation(seq: usize) -> Workload {
        Workload {
            tokens: 1,
            seq,
            head_dim: 64,
            qk_lanes: 64,
            pv_lanes: 64,
            norm_latency: 4,
        }
    }

    pub fn summarization(tokens: usize, seq: usize) -> Workload {
        Workload { tokens, ..Workload::paper_generation(seq) }
    }

    pub fn qk_cycles_per_elem(&self) -> u64 {
        self.head_dim.div_ceil(self.qk_lanes) as u64
    }

    pub fn pv_cycles_per_elem(&self) -> u64 {
        self.head_dim.div_ceil(self.pv_lanes) as u64
    }
}

/// Busy-interval bookkeeping for one module.
#[derive(Debug, Clone, Default)]
pub struct ModuleStats {
    pub busy_cycles: u64,
    /// (start, end) segments, merged, for timeline rendering.
    pub segments: Vec<(u64, u64)>,
}

impl ModuleStats {
    fn add(&mut self, start: u64, end: u64) {
        debug_assert!(end >= start);
        self.busy_cycles += end - start;
        match self.segments.last_mut() {
            Some(last) if last.1 == start => last.1 = end,
            _ => self.segments.push((start, end)),
        }
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub norm: NormKind,
    pub schedule: Schedule,
    pub total_cycles: u64,
    pub qk: ModuleStats,
    pub norm_unit: ModuleStats,
    pub pv: ModuleStats,
}

impl SimResult {
    /// Mean hardware utilization across the three modules.
    pub fn utilization(&self) -> f64 {
        let busy = (self.qk.busy_cycles + self.norm_unit.busy_cycles + self.pv.busy_cycles) as f64;
        busy / (3.0 * self.total_cycles as f64)
    }

    pub fn speedup_over(&self, other: &SimResult) -> f64 {
        other.total_cycles as f64 / self.total_cycles as f64
    }
}

/// Run the pipeline simulation.
///
/// Panics if `ElementWise` is requested for a non-streaming normalizer —
/// that hardware cannot exist (the max/sum barrier is semantic, not a
/// scheduling choice), and the type-level guard documents the paper's
/// core argument.
pub fn simulate(w: &Workload, norm: NormKind, schedule: Schedule) -> SimResult {
    if schedule == Schedule::ElementWise {
        assert!(
            norm.is_streaming(),
            "{} requires a max/sum barrier; the element-wise schedule is \
             only realizable for ConSmax (paper §IV-B)",
            norm.name()
        );
    }
    let qk_cpe = w.qk_cycles_per_elem();
    let pv_cpe = w.pv_cycles_per_elem();

    let mut qk = ModuleStats::default();
    let mut norm_unit = ModuleStats::default();
    let mut pv = ModuleStats::default();

    // Module-free timestamps.
    let mut qk_free: u64 = 0;
    let mut pv_free: u64 = 0;
    let mut norm_free: u64 = 0;
    let mut last_pv_end: u64 = 0;

    for _tok in 0..w.tokens {
        // ---- QK: produce seq score elements back to back --------------
        let mut arrivals = Vec::with_capacity(w.seq);
        let mut t = qk_free;
        for _ in 0..w.seq {
            let start = t;
            let end = start + qk_cpe;
            qk.add(start, end);
            arrivals.push(end);
            t = end;
        }
        qk_free = t;

        // ---- Normalizer: per-design emission times --------------------
        let last_arrival = *arrivals.last().unwrap();
        let mut emissions = Vec::with_capacity(w.seq);
        match norm {
            NormKind::ConSmax => {
                // streaming: each element normalized `lat` after arrival,
                // II = 1 through the unit
                let mut prev_end = norm_free;
                for &a in &arrivals {
                    let start = a.max(prev_end);
                    let end = start + 1;
                    norm_unit.add(start, end);
                    emissions.push(end + w.norm_latency);
                    prev_end = end;
                }
                norm_free = prev_end;
            }
            NormKind::Softermax => {
                // online pass tracks arrivals (unit busy as elements
                // arrive); emission pass starts after the last arrival
                // (+ reciprocal latency), 1 elem/cycle.
                let mut prev_end = norm_free;
                for &a in &arrivals {
                    let start = a.max(prev_end);
                    let end = start + 1;
                    norm_unit.add(start, end);
                    prev_end = end;
                }
                let emit_start = prev_end.max(last_arrival) + w.norm_latency;
                for i in 0..w.seq as u64 {
                    norm_unit.add(emit_start + i, emit_start + i + 1);
                    emissions.push(emit_start + i + 1);
                }
                norm_free = emit_start + w.seq as u64;
            }
            NormKind::Softmax => {
                // running max during arrival (busy), THEN a full exp/sum
                // pass over the buffered vector, THEN the divide/emit pass.
                let mut prev_end = norm_free;
                for &a in &arrivals {
                    let start = a.max(prev_end);
                    let end = start + 1;
                    norm_unit.add(start, end);
                    prev_end = end;
                }
                let sum_start = prev_end.max(last_arrival);
                let sum_end = sum_start + w.seq as u64; // exp+accumulate pass
                norm_unit.add(sum_start, sum_end);
                let emit_start = sum_end + w.norm_latency;
                for i in 0..w.seq as u64 {
                    norm_unit.add(emit_start + i, emit_start + i + 1);
                    emissions.push(emit_start + i + 1);
                }
                norm_free = emit_start + w.seq as u64;
            }
            NormKind::PartialSoftmax { chunks } => {
                // each chunk локally softmaxed when its last element
                // arrives (chunk-sized pass), then a global rescale pass
                // after ALL chunks complete (the synchronization overhead
                // FlashDecoding++ measures at ~20%).
                let chunks = chunks.max(1).min(w.seq);
                let chunk_len = w.seq / chunks;
                let mut local_done: u64 = norm_free;
                for c in 0..chunks {
                    let lo = c * chunk_len;
                    let hi = if c + 1 == chunks { w.seq } else { lo + chunk_len };
                    let chunk_last = arrivals[hi - 1];
                    let start = chunk_last.max(local_done);
                    let end = start + (hi - lo) as u64; // local exp/sum pass
                    norm_unit.add(start, end);
                    local_done = end;
                }
                // global merge of maxes/sums: ~chunks cycles, then rescale
                let merge_end = local_done + chunks as u64;
                norm_unit.add(local_done, merge_end);
                let emit_start = merge_end + w.norm_latency;
                for i in 0..w.seq as u64 {
                    norm_unit.add(emit_start + i, emit_start + i + 1);
                    emissions.push(emit_start + i + 1);
                }
                norm_free = emit_start + w.seq as u64;
            }
        }

        // ---- PV: consume probability elements --------------------------
        match schedule {
            Schedule::ElementWise => {
                let mut prev = pv_free;
                for &e in &emissions {
                    let start = e.max(prev);
                    let end = start + pv_cpe;
                    pv.add(start, end);
                    prev = end;
                }
                pv_free = prev;
            }
            Schedule::TokenPipeline => {
                // PV waits for the whole normalized token (double-buffer
                // handoff), then streams it.
                let token_ready = *emissions.last().unwrap();
                let mut prev = pv_free.max(token_ready);
                for _ in 0..w.seq {
                    let start = prev;
                    let end = start + pv_cpe;
                    pv.add(start, end);
                    prev = end;
                }
                pv_free = prev;
            }
        }
        last_pv_end = pv_free;
    }

    SimResult {
        norm,
        schedule,
        total_cycles: last_pv_end,
        qk,
        norm_unit,
        pv,
    }
}

/// Fig 5 headline: generation-stage time saving of ConSmax element-wise
/// over Softmax token-pipeline at a given context size.
pub fn fig5_time_saving(seq: usize) -> (SimResult, SimResult, f64) {
    let w = Workload::paper_generation(seq);
    let base = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
    let cons = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
    let saving = 1.0 - cons.total_cycles as f64 / base.total_cycles as f64;
    (base, cons, saving)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(seq: usize) -> Workload {
        Workload::paper_generation(seq)
    }

    #[test]
    fn consmax_elementwise_beats_softmax_token_pipeline() {
        let (base, cons, saving) = fig5_time_saving(256);
        assert!(cons.total_cycles < base.total_cycles);
        // structure: softmax serializes QK(seq) + sum pass(seq) + emit(seq)
        // + PV(seq) ≈ 4*seq; consmax overlaps everything ≈ seq. Expect
        // >= 50% saving.
        assert!(saving > 0.5, "saving {saving}");
    }

    #[test]
    fn consmax_generation_total_near_streaming_bound() {
        let w = gen(256);
        let r = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
        // lower bound: seq elements through the slowest stage + fill
        let bound = 256 * w.qk_cycles_per_elem().max(w.pv_cycles_per_elem());
        assert!(r.total_cycles < bound + 64, "{} vs {bound}", r.total_cycles);
    }

    #[test]
    fn softmax_generation_serializes() {
        let w = gen(256);
        let r = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
        // must pay at least arrival + sum pass + emit + PV stream
        assert!(r.total_cycles >= 4 * 256);
    }

    #[test]
    #[should_panic(expected = "element-wise schedule")]
    fn elementwise_softmax_is_impossible() {
        simulate(&gen(64), NormKind::Softmax, Schedule::ElementWise);
    }

    #[test]
    #[should_panic(expected = "element-wise schedule")]
    fn elementwise_partial_softmax_is_impossible() {
        simulate(
            &gen(64),
            NormKind::PartialSoftmax { chunks: 4 },
            Schedule::ElementWise,
        );
    }

    #[test]
    fn work_conservation_qk_pv() {
        // QK and PV busy cycles are schedule-invariant (same math done).
        for norm in [NormKind::Softmax, NormKind::Softermax, NormKind::ConSmax] {
            let w = Workload::summarization(8, 128);
            let r = simulate(&w, norm, Schedule::TokenPipeline);
            let expect_qk = 8 * 128 * w.qk_cycles_per_elem();
            let expect_pv = 8 * 128 * w.pv_cycles_per_elem();
            assert_eq!(r.qk.busy_cycles, expect_qk, "{:?}", norm);
            assert_eq!(r.pv.busy_cycles, expect_pv, "{:?}", norm);
        }
    }

    #[test]
    fn softermax_cheaper_than_softmax_dearer_than_consmax() {
        let w = gen(512);
        let sm = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline).total_cycles;
        let so = simulate(&w, NormKind::Softermax, Schedule::TokenPipeline).total_cycles;
        let cs = simulate(&w, NormKind::ConSmax, Schedule::ElementWise).total_cycles;
        assert!(cs < so && so < sm, "cs={cs} so={so} sm={sm}");
    }

    #[test]
    fn partial_softmax_sync_cost_matches_flashdecoding_claim() {
        // paper §III-B: partial-softmax synchronization accounts for
        // ~18.8% of attention latency at 1024 tokens. In our pipeline the
        // synchronization is the global merge + rescale pass (seq +
        // chunks cycles); as a share of end-to-end latency it should land
        // in the 15–45% band, and partial softmax must be strictly slower
        // than the online (softermax-style) single-barrier design.
        let w = gen(1024);
        let ps = simulate(&w, NormKind::PartialSoftmax { chunks: 8 }, Schedule::TokenPipeline);
        let so = simulate(&w, NormKind::Softermax, Schedule::TokenPipeline);
        assert!(ps.total_cycles > so.total_cycles);
        let sync_cycles = (w.seq + 8) as f64;
        let share = sync_cycles / ps.total_cycles as f64;
        assert!((0.15..0.45).contains(&share), "sync share {share}");
    }

    #[test]
    fn utilization_consmax_near_one_softmax_low_in_generation() {
        let w = gen(1024);
        let cs = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
        let sm = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
        // Fig 5's underutilization story (norm unit occupancy differs by
        // design so compare QK+PV duty):
        let duty = |r: &SimResult| {
            (r.qk.busy_cycles + r.pv.busy_cycles) as f64 / (2.0 * r.total_cycles as f64)
        };
        assert!(duty(&cs) > 0.9, "consmax duty {}", duty(&cs));
        assert!(duty(&sm) < 0.4, "softmax duty {}", duty(&sm));
    }

    #[test]
    fn summarization_token_pipeline_overlaps_tokens() {
        // with many tokens, the token pipeline amortizes the barrier:
        // throughput per token must improve vs a single token
        let one = simulate(&gen(256), NormKind::Softmax, Schedule::TokenPipeline);
        let many = simulate(
            &Workload::summarization(16, 256),
            NormKind::Softmax,
            Schedule::TokenPipeline,
        );
        let per_tok_one = one.total_cycles as f64;
        let per_tok_many = many.total_cycles as f64 / 16.0;
        // the norm unit is the serial bottleneck (3 passes/token through
        // one unit), so the amortization is modest but must be real
        assert!(per_tok_many < per_tok_one * 0.95, "{per_tok_many} vs {per_tok_one}");
        // and the QK module's duty cycle must rise with pipelining
        let duty = |r: &SimResult| r.qk.busy_cycles as f64 / r.total_cycles as f64;
        assert!(duty(&many) > 1.25 * duty(&one), "{} vs {}", duty(&many), duty(&one));
    }

    #[test]
    fn longer_context_widens_the_gap() {
        // the paper's motivation: softmax overhead grows with context
        let s = |seq| {
            let (_, _, saving) = fig5_time_saving(seq);
            saving
        };
        assert!(s(4096) >= s(256) - 1e-9);
    }

    #[test]
    fn segments_are_ordered_and_disjoint() {
        let w = Workload::summarization(4, 64);
        for norm in [NormKind::Softmax, NormKind::Softermax, NormKind::ConSmax] {
            let r = simulate(&w, norm, Schedule::TokenPipeline);
            for m in [&r.qk, &r.norm_unit, &r.pv] {
                for win in m.segments.windows(2) {
                    assert!(win[0].1 <= win[1].0, "{:?}", win);
                }
                let seg_sum: u64 = m.segments.iter().map(|(a, b)| b - a).sum();
                assert_eq!(seg_sum, m.busy_cycles);
            }
        }
    }

    #[test]
    fn mismatched_lanes_respected() {
        let w = Workload {
            tokens: 1,
            seq: 128,
            head_dim: 64,
            qk_lanes: 16, // 4 cycles per score
            pv_lanes: 64, // 1 cycle per element
            norm_latency: 4,
        };
        let r = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
        // QK is the bottleneck: total ≈ 128 * 4
        assert!(r.total_cycles >= 512);
        assert!(r.total_cycles < 512 + 32);
    }
}
