//! Accelerator-level integration (paper §IV-B, Fig 4b): combine the
//! cycle-accurate pipeline schedule with the synthesis estimator's power
//! figures to get **end-to-end attention latency and energy per token**
//! for a whole model configuration — the number a deployment actually
//! cares about, and the quantitative form of the paper's "integrate
//! ConSmax hardware to transformer accelerator" argument.
//!
//! Energy model: normalizer energy = unit power × busy time; tensor-core
//! energy = MACs × energy/MAC (identical across designs — the matmuls
//! don't change); idle leakage charged for stall cycles, which is where
//! the token-pipeline's serialization hurts twice.

use crate::hw::designs::{consmax_unit, softermax_unit, softmax_unit, Precision};
use crate::hw::synth::Synthesizer;
use crate::hw::tech::{EdaFlow, TechNode, TechProfile};
use crate::sim::pipeline::{simulate, NormKind, Schedule, Workload};

/// A model-level attention configuration (per layer, per head).
#[derive(Debug, Clone, Copy)]
pub struct AttentionConfig {
    pub n_layer: usize,
    pub n_head: usize,
    pub head_dim: usize,
    pub seq: usize,
}

impl AttentionConfig {
    /// The paper's GPT benchmark (6L/6H/384 → head_dim 64, ctx 256).
    pub fn paper_gpt() -> AttentionConfig {
        AttentionConfig { n_layer: 6, n_head: 6, head_dim: 64, seq: 256 }
    }

    /// GPT-2 small (12L/12H/768) at 1K context.
    pub fn gpt2_small_1k() -> AttentionConfig {
        AttentionConfig { n_layer: 12, n_head: 12, head_dim: 64, seq: 1024 }
    }
}

/// End-to-end figures for one (design, schedule) at one corner.
#[derive(Debug, Clone)]
pub struct AccelReport {
    pub design: String,
    /// Latency of one generated token through all layers/heads (µs).
    pub token_latency_us: f64,
    /// Normalizer energy per generated token (nJ).
    pub norm_energy_nj: f64,
    /// Tensor-core (QK+PV) energy per token (nJ) — design-independent.
    pub tensorcore_energy_nj: f64,
    /// Normalizer leakage burned during stalls (nJ).
    pub stall_leakage_nj: f64,
    pub utilization: f64,
}

/// MAC energy at the corner (pJ): an 8-bit MAC in the tensor core.
fn mac_energy_pj(profile: &TechProfile) -> f64 {
    0.025 * profile.energy_scale
}

/// Evaluate one normalizer design integrated into the accelerator.
pub fn evaluate(
    cfg: &AttentionConfig,
    norm: NormKind,
    node: TechNode,
    flow: EdaFlow,
    freq_mhz: f64,
) -> AccelReport {
    let profile = TechProfile::new(node, flow);
    let synth = Synthesizer::new(profile);
    let (design, schedule) = match norm {
        NormKind::ConSmax => (consmax_unit(Precision::Int8), Schedule::ElementWise),
        NormKind::Softermax => (softermax_unit(cfg.seq), Schedule::TokenPipeline),
        NormKind::Softmax | NormKind::PartialSoftmax { .. } => {
            (softmax_unit(cfg.seq), Schedule::TokenPipeline)
        }
    };
    let rep = synth.synthesize(&design);
    let f = freq_mhz.min(rep.fmax_mhz);

    // one head's generation-stage schedule; heads run sequentially on the
    // (single) pipeline per layer — per-token work scales linearly
    let w = Workload {
        tokens: 1,
        seq: cfg.seq,
        head_dim: cfg.head_dim,
        qk_lanes: cfg.head_dim,
        pv_lanes: cfg.head_dim,
        norm_latency: 4,
    };
    let sim = simulate(&w, norm, schedule);
    let units = (cfg.n_layer * cfg.n_head) as f64;

    let cycle_s = 1e-6 / f; // seconds per cycle at f MHz
    let token_latency_us = sim.total_cycles as f64 * units * cycle_s * 1e6;

    // normalizer dynamic energy: elements processed × energy/elem
    let elems = (cfg.seq) as f64 * units;
    let norm_dyn_nj = elems * rep.energy_pj_per_elem_nominal * 1e-3;
    // leakage during the whole schedule (busy or not)
    let norm_leak_nj =
        rep.leakage_mw_nominal * (sim.total_cycles as f64 * units * cycle_s) * 1e6
            * 1e-3;
    // stall share of that leakage
    let stall_frac = 1.0
        - sim.norm_unit.busy_cycles as f64 / sim.total_cycles.max(1) as f64;

    // tensor cores: QK + PV MACs per token = 2 * seq * head_dim per head
    let macs = 2.0 * cfg.seq as f64 * cfg.head_dim as f64 * units;
    let tc_nj = macs * mac_energy_pj(&synth.profile) * 1e-3;

    AccelReport {
        design: norm.name(),
        token_latency_us,
        norm_energy_nj: norm_dyn_nj + norm_leak_nj,
        tensorcore_energy_nj: tc_nj,
        stall_leakage_nj: norm_leak_nj * stall_frac,
        utilization: sim.utilization(),
    }
}

/// The three designs side by side at a corner.
pub fn compare_designs(
    cfg: &AttentionConfig,
    node: TechNode,
    flow: EdaFlow,
    freq_mhz: f64,
) -> Vec<AccelReport> {
    [NormKind::Softmax, NormKind::Softermax, NormKind::ConSmax]
        .into_iter()
        .map(|n| evaluate(cfg, n, node, flow, freq_mhz))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consmax_wins_latency_and_energy() {
        let cfg = AttentionConfig::paper_gpt();
        let reports =
            compare_designs(&cfg, TechNode::Fin16, EdaFlow::Proprietary, 500.0);
        let (sm, so, cs) = (&reports[0], &reports[1], &reports[2]);
        assert!(cs.token_latency_us < so.token_latency_us);
        assert!(so.token_latency_us < sm.token_latency_us);
        assert!(cs.norm_energy_nj < sm.norm_energy_nj);
        assert!(cs.utilization > sm.utilization);
    }

    #[test]
    fn tensorcore_energy_is_design_independent() {
        let cfg = AttentionConfig::paper_gpt();
        let reports =
            compare_designs(&cfg, TechNode::Fin16, EdaFlow::Proprietary, 500.0);
        assert_eq!(reports[0].tensorcore_energy_nj, reports[1].tensorcore_energy_nj);
        assert_eq!(reports[1].tensorcore_energy_nj, reports[2].tensorcore_energy_nj);
    }

    #[test]
    fn normalizer_share_shrinks_for_consmax() {
        // the paper's framing: softmax is a disproportionate share of
        // attention cost; ConSmax pushes it into the noise
        let cfg = AttentionConfig::gpt2_small_1k();
        let reports =
            compare_designs(&cfg, TechNode::Fin16, EdaFlow::Proprietary, 500.0);
        let share = |r: &AccelReport| {
            r.norm_energy_nj / (r.norm_energy_nj + r.tensorcore_energy_nj)
        };
        assert!(share(&reports[2]) < 0.15, "consmax share {}", share(&reports[2]));
        assert!(share(&reports[0]) > share(&reports[2]));
    }

    #[test]
    fn latency_scales_with_model_size() {
        let small = AttentionConfig::paper_gpt();
        let big = AttentionConfig::gpt2_small_1k();
        let a = evaluate(&small, NormKind::ConSmax, TechNode::Fin16,
                         EdaFlow::Proprietary, 500.0);
        let b = evaluate(&big, NormKind::ConSmax, TechNode::Fin16,
                         EdaFlow::Proprietary, 500.0);
        assert!(b.token_latency_us > 3.0 * a.token_latency_us);
    }

    #[test]
    fn stall_leakage_negligible_for_consmax() {
        let cfg = AttentionConfig::paper_gpt();
        let cs = evaluate(&cfg, NormKind::ConSmax, TechNode::Fin16,
                          EdaFlow::Proprietary, 500.0);
        let sm = evaluate(&cfg, NormKind::Softmax, TechNode::Fin16,
                          EdaFlow::Proprietary, 500.0);
        assert!(cs.stall_leakage_nj < sm.stall_leakage_nj);
    }
}
