//! Cycle-accurate simulator of the attention accelerator of Fig 2 /
//! Fig 4(b): a QK module, a score-normalization module and a PV module
//! connected by double buffers.
//!
//! Two schedules are modelled:
//!
//! * **Token pipeline** (Fig 2, SpAtten/ELSA-style): the normalizer owns a
//!   whole token's score vector; PV for token *t* cannot start until the
//!   normalizer finishes token *t*. Across tokens the three modules overlap.
//! * **Element-wise pipeline** (Fig 4b, ConSmax only): normalized elements
//!   stream straight into PV; no per-token barrier exists because ConSmax
//!   needs no max/sum.
//!
//! The simulator is exact at cycle granularity: module service times are
//! deterministic, so the event-driven schedule it computes is identical to
//! a per-cycle RTL-level simulation of the same dataflow (asserted by the
//! conservation properties in `rust/tests/properties.rs`).

pub mod accelerator;
pub mod pipeline;

pub use accelerator::{compare_designs, evaluate, AccelReport, AttentionConfig};
pub use pipeline::{simulate, NormKind, Schedule, SimResult, Workload};
