//! Data substrate: corpus, tokenizer, batching.
//!
//! The paper trains on WikiText103, which is unavailable offline (541 MB,
//! license-gated download). Per DESIGN.md §2 we substitute (a) a bundled
//! tiny English corpus for smoke-scale runs and (b) a deterministic
//! synthetic generator with Zipfian unigram statistics and Markov bigram
//! structure for volume — what matters to the experiment (Softmax vs
//! ConSmax convergence parity on identical data) is preserved by any
//! stationary text-like stream.
//!
//! Tokenization is byte-level (vocab 256), matching the model's embedding
//! table; no merges, no OOV, fully reversible.

pub mod corpus;

pub use corpus::{synthetic_corpus, Corpus, TINY_CORPUS};

use crate::util::rng::Pcg32;

/// Byte-level tokenizer (identity over UTF-8 bytes).
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .map(|&t| t.clamp(0, 255) as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// Sliding-window (x, y) batch sampler over a token stream.
#[derive(Debug)]
pub struct BatchSampler {
    tokens: Vec<i32>,
    rng: Pcg32,
    pub batch: usize,
    pub ctx: usize,
}

impl BatchSampler {
    pub fn new(tokens: Vec<i32>, batch: usize, ctx: usize, seed: u64) -> BatchSampler {
        assert!(
            tokens.len() > ctx + 1,
            "corpus too small: {} tokens for ctx {}",
            tokens.len(),
            ctx
        );
        BatchSampler { tokens, rng: Pcg32::seeded(seed), batch, ctx }
    }

    /// Sample a batch: x = windows, y = x shifted by one.
    /// Returned flat, row-major (batch, ctx).
    pub fn sample(&mut self) -> (Vec<i32>, Vec<i32>) {
        let mut x = Vec::with_capacity(self.batch * self.ctx);
        let mut y = Vec::with_capacity(self.batch * self.ctx);
        for _ in 0..self.batch {
            let start = self
                .rng
                .below((self.tokens.len() - self.ctx - 1) as u64)
                as usize;
            x.extend_from_slice(&self.tokens[start..start + self.ctx]);
            y.extend_from_slice(&self.tokens[start + 1..start + self.ctx + 1]);
        }
        (x, y)
    }

    /// Deterministic evaluation batches covering the stream without
    /// overlap (for the validation-loss curves of Fig 6).
    pub fn eval_batches(&self, max_batches: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
        let mut out = Vec::new();
        let stride = self.ctx + 1;
        let mut pos = 0;
        'outer: for _ in 0..max_batches {
            let mut x = Vec::with_capacity(self.batch * self.ctx);
            let mut y = Vec::with_capacity(self.batch * self.ctx);
            for _ in 0..self.batch {
                if pos + stride >= self.tokens.len() {
                    break 'outer;
                }
                x.extend_from_slice(&self.tokens[pos..pos + self.ctx]);
                y.extend_from_slice(&self.tokens[pos + 1..pos + self.ctx + 1]);
                pos += stride;
            }
            out.push((x, y));
        }
        out
    }

    pub fn token_count(&self) -> usize {
        self.tokens.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "The quick brown fox! 123";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn tokenizer_roundtrip_utf8() {
        let t = ByteTokenizer;
        let s = "héllo wörld — ConSmax";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn tokens_in_vocab() {
        let t = ByteTokenizer;
        for tok in t.encode("any text at all ∞") {
            assert!((0..256).contains(&tok));
        }
    }

    #[test]
    fn batch_shapes() {
        let toks: Vec<i32> = (0..1000).map(|i| i % 256).collect();
        let mut s = BatchSampler::new(toks, 4, 32, 0);
        let (x, y) = s.sample();
        assert_eq!(x.len(), 4 * 32);
        assert_eq!(y.len(), 4 * 32);
    }

    #[test]
    fn y_is_x_shifted() {
        let toks: Vec<i32> = (0..500).map(|i| i % 251) .collect();
        let mut s = BatchSampler::new(toks, 2, 16, 1);
        let (x, y) = s.sample();
        for row in 0..2 {
            for i in 0..15 {
                assert_eq!(x[row * 16 + i + 1], y[row * 16 + i]);
            }
        }
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let toks: Vec<i32> = (0..500).map(|i| (i * 7) % 256).collect();
        let mut a = BatchSampler::new(toks.clone(), 2, 16, 42);
        let mut b = BatchSampler::new(toks, 2, 16, 42);
        assert_eq!(a.sample(), b.sample());
    }

    #[test]
    fn eval_batches_nonoverlapping() {
        let toks: Vec<i32> = (0..2000).map(|i| i % 256).collect();
        let s = BatchSampler::new(toks, 2, 32, 0);
        let batches = s.eval_batches(5);
        assert!(!batches.is_empty());
        // first tokens of consecutive rows differ by stride
        let (x0, _) = &batches[0];
        assert_eq!(x0[0], 0);
        assert_eq!(x0[32], 33); // next row starts at pos 33
    }

    #[test]
    #[should_panic(expected = "corpus too small")]
    fn tiny_corpus_rejected() {
        BatchSampler::new(vec![1, 2, 3], 1, 16, 0);
    }
}
