//! Corpora: a bundled tiny English text and a deterministic synthetic
//! generator with WikiText-like statistics (Zipfian unigrams over a word
//! inventory + Markov sentence structure). See module docs in `mod.rs`
//! for why this substitutes for WikiText103.

use crate::util::rng::Pcg32;

/// A training corpus: raw text + provenance tag.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub name: String,
    pub text: String,
}

impl Corpus {
    pub fn tiny() -> Corpus {
        Corpus { name: "tiny-english".into(), text: TINY_CORPUS.repeat(4) }
    }

    pub fn synthetic(words: usize, seed: u64) -> Corpus {
        Corpus {
            name: format!("synthetic-{words}w-s{seed}"),
            text: synthetic_corpus(words, seed),
        }
    }

    /// Load from a file (for users with a real WikiText103 dump).
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Corpus> {
        Ok(Corpus {
            name: path.display().to_string(),
            text: std::fs::read_to_string(path)?,
        })
    }

    pub fn len_bytes(&self) -> usize {
        self.text.len()
    }

    /// 90/10 train/validation split at a sentence-ish boundary.
    pub fn split(&self) -> (&str, &str) {
        let cut = (self.text.len() * 9) / 10;
        let cut = self.text[..cut]
            .rfind(". ")
            .map(|i| i + 2)
            .unwrap_or(cut);
        (&self.text[..cut], &self.text[cut..])
    }
}

/// Bundled seed text (public-domain-style prose written for this repo;
/// statistics comparable to encyclopedic English).
pub const TINY_CORPUS: &str = "\
The transformer architecture changed how machines process language. \
Attention lets every token look at every other token, and the softmax \
function turns raw similarity scores into a probability distribution. \
Computing softmax requires finding the maximum score and summing the \
exponentials, which forces the hardware to wait for the whole score \
vector before any output can be produced. The constant softmax replaces \
the maximum and the denominator with two learnable parameters, so each \
score can be normalized the moment it arrives. A small lookup table \
stores the exponential of the high bits and the low bits separately, and \
a half precision multiplier merges the two factors without any loss of \
accuracy. During training the two parameters drift toward values that \
keep the attention probabilities well scaled, and during inference they \
are folded into a single constant. The hardware that results is small, \
fast, and cool, because it never buffers the score vector and never \
divides. Long contexts make the difference larger, since the buffers in \
the ordinary design grow with the sequence while the constant design \
stays the same size. An accelerator built this way keeps its multiply \
units busy even when generating one token at a time, which is exactly \
the case that matters for interactive use. The language model head still \
uses the ordinary softmax, because the output distribution must sum to \
one for sampling, but inside the attention blocks the constant form is \
enough to tell strong matches from weak ones. Careful initialization of \
the two parameters shortens the unstable phase at the start of training. \
Measurements on a small model show the two curves meeting after enough \
iterations, with the constant form briefly behind early on. Silicon area \
and power both drop by large factors when the comparison is made against \
a faithful implementation of the ordinary function, and the advantage \
persists across process nodes and tool chains. ";

/// Word inventory for the synthetic generator (mixed-frequency content
/// and function words).
const FUNCTION_WORDS: &[&str] = &[
    "the", "of", "and", "a", "to", "in", "is", "was", "it", "for", "with",
    "as", "on", "that", "by", "this", "at", "from", "are", "an", "be",
    "or", "which", "were", "but", "not", "its", "also", "has", "had",
];

const CONTENT_WORDS: &[&str] = &[
    "attention", "model", "token", "score", "softmax", "hardware", "layer",
    "training", "language", "sequence", "vector", "memory", "parameter",
    "function", "design", "power", "area", "energy", "silicon", "buffer",
    "multiplier", "lookup", "table", "precision", "constant", "gradient",
    "context", "pipeline", "module", "accelerator", "throughput", "latency",
    "network", "weight", "value", "query", "key", "head", "block", "unit",
    "distribution", "probability", "maximum", "summation", "exponential",
    "normalization", "synthesis", "frequency", "voltage", "technology",
    "measurement", "iteration", "convergence", "perplexity", "dataset",
    "inference", "generation", "decoder", "embedding", "projection",
];

/// Deterministic synthetic text: Zipf-weighted unigrams with light
/// bigram structure (function word ↔ content word alternation bias) and
/// sentence/paragraph punctuation. Statistically stationary, byte-level
/// entropy comparable to prose.
pub fn synthetic_corpus(words: usize, seed: u64) -> String {
    let mut rng = Pcg32::seeded(seed ^ 0x5EED_C0FF);
    // Zipf weights over the combined inventory
    let func_w: Vec<f64> =
        (0..FUNCTION_WORDS.len()).map(|i| 1.0 / (i + 1) as f64).collect();
    let cont_w: Vec<f64> =
        (0..CONTENT_WORDS.len()).map(|i| 1.0 / (i + 2) as f64).collect();

    let mut out = String::with_capacity(words * 7);
    let mut sentence_len = 0usize;
    let mut want_content = false;
    for i in 0..words {
        let word = if want_content || rng.uniform() < 0.55 {
            CONTENT_WORDS[rng.weighted(&cont_w)]
        } else {
            FUNCTION_WORDS[rng.weighted(&func_w)]
        };
        // bias alternation: content follows function more often
        want_content = !want_content && rng.uniform() < 0.6;

        if sentence_len == 0 {
            // capitalize
            let mut cs = word.chars();
            if let Some(c0) = cs.next() {
                out.extend(c0.to_uppercase());
                out.push_str(cs.as_str());
            }
        } else {
            out.push_str(word);
        }
        sentence_len += 1;

        let end_sentence = sentence_len >= 6 && rng.uniform() < 0.18;
        if end_sentence || i + 1 == words {
            out.push('.');
            out.push(' ');
            sentence_len = 0;
            if rng.uniform() < 0.12 {
                out.push('\n');
            }
        } else {
            out.push(' ');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn tiny_corpus_is_substantial() {
        let c = Corpus::tiny();
        assert!(c.len_bytes() > 4000);
    }

    #[test]
    fn split_gives_both_parts() {
        let c = Corpus::tiny();
        let (train, val) = c.split();
        assert!(train.len() > 5 * val.len() / 2);
        assert!(!val.is_empty());
        assert_eq!(train.len() + val.len(), c.text.len());
    }

    #[test]
    fn synthetic_is_deterministic() {
        assert_eq!(synthetic_corpus(500, 7), synthetic_corpus(500, 7));
        assert_ne!(synthetic_corpus(500, 7), synthetic_corpus(500, 8));
    }

    #[test]
    fn synthetic_has_requested_scale() {
        let text = synthetic_corpus(10_000, 1);
        let words = text.split_whitespace().count();
        assert!((9_000..=11_000).contains(&words), "{words}");
    }

    #[test]
    fn synthetic_unigrams_are_zipfian() {
        // most-common word should dominate the tail strongly
        let text = synthetic_corpus(20_000, 3);
        let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
        for w in text.split_whitespace() {
            let w = w.trim_matches(|c: char| !c.is_alphanumeric());
            if !w.is_empty() {
                *counts.entry(w).or_default() += 1;
            }
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        assert!(freqs[0] > 4 * freqs[freqs.len() / 2], "{:?}", &freqs[..5]);
    }

    #[test]
    fn synthetic_has_sentences() {
        let text = synthetic_corpus(2_000, 4);
        let sentences = text.matches(". ").count();
        assert!(sentences > 50, "{sentences}");
    }

    #[test]
    fn synthetic_is_ascii_byte_friendly() {
        let text = synthetic_corpus(1_000, 5);
        assert!(text.is_ascii());
    }
}
