//! Run configuration: a typed view over the artifact manifest plus the
//! coordinator's own knobs. Everything the Rust side needs to know about
//! a model variant comes from `artifacts/manifest.json` (written by
//! `python/compile/aot.py`), keeping the two languages in lock-step.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::fp16::{Bf16, F16};
use crate::util::json::Json;

/// Storage precision of the paged KV cache (DESIGN.md §KV-memory seam,
/// §Quantization seam).
///
/// ConSmax's merged `C·exp(S)` form needs no row-max search, so reduced
/// precision K/V feed the score→exp→PV stream directly; `F16`/`Bf16`
/// halve resident KV bytes per token and `Int8` quarters them (one i8
/// code per element plus one f32 power-of-two scale per stored
/// `head_dim` vector). `F32` is the bit-exact oracle precision (a paged
/// f32 session decodes bitwise identically to the dense layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvDtype {
    F32,
    F16,
    Bf16,
    Int8,
}

impl KvDtype {
    pub fn parse(s: &str) -> Result<KvDtype> {
        Ok(match s {
            "f32" | "fp32" => KvDtype::F32,
            "f16" | "fp16" | "half" => KvDtype::F16,
            "bf16" | "bfloat16" => KvDtype::Bf16,
            "int8" | "i8" => KvDtype::Int8,
            other => bail!("unknown kv dtype {other:?} (f32|f16|bf16|int8)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::Bf16 => "bf16",
            KvDtype::Int8 => "int8",
        }
    }

    pub fn bytes_per_elem(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::F16 | KvDtype::Bf16 => 2,
            KvDtype::Int8 => 1,
        }
    }

    /// Encode→decode round trip of one value: what a reader of the KV
    /// store will observe after `x` is written at this precision. For
    /// `F32` this is the identity (bit-preserving). `Int8` is quantized
    /// per stored `head_dim` vector (the scale depends on the whole
    /// vector — see [`KvDtype::roundtrip_vec`]); the scalar form treats
    /// `x` as a one-element vector.
    pub fn roundtrip(self, x: f32) -> f32 {
        match self {
            KvDtype::F32 => x,
            KvDtype::F16 => F16::from_f32(x).to_f32(),
            KvDtype::Bf16 => Bf16::from_f32(x).to_f32(),
            KvDtype::Int8 => {
                let mut v = [x];
                self.roundtrip_vec(&mut v);
                v[0]
            }
        }
    }

    /// Encode→decode round trip of one stored `head_dim` vector in
    /// place. Float dtypes round element-wise; `Int8` quantizes the
    /// whole vector against a single power-of-two scale fitted to its
    /// max-abs — the exact math `KvPool` applies at `write_token` /
    /// `write_capture`. Power-of-two scales make the transform
    /// idempotent: re-fitting already-roundtripped values reproduces
    /// the same bits, so a decode step may stage through this helper
    /// and commit the staged values to an int8 pool without drift.
    pub fn roundtrip_vec(self, v: &mut [f32]) {
        match self {
            KvDtype::F32 => {}
            KvDtype::F16 | KvDtype::Bf16 => {
                for x in v.iter_mut() {
                    *x = self.roundtrip(*x);
                }
            }
            KvDtype::Int8 => {
                let scale = crate::quant::kv_vec_scale(v);
                for x in v.iter_mut() {
                    *x = crate::quant::dequantize_i8(
                        crate::quant::quantize_i8(*x, scale),
                        scale,
                    );
                }
            }
        }
    }
}

/// Serving-path quantization mode (`--quant`, DESIGN.md §Quantization
/// seam). `Int8` swaps every projection matmul (and the tied LM head)
/// onto per-output-channel symmetric int8 weights quantized once at
/// model load, and — for ConSmax models — computes the C·exp attention
/// tail through the bit-split LUT, bit-identical to
/// [`BitSplitLut`](crate::quant::BitSplitLut) and the RTL simulator.
/// `Off` keeps the f32 kernels as the oracle path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QuantMode {
    #[default]
    Off,
    Int8,
}

impl QuantMode {
    pub fn parse(s: &str) -> Result<QuantMode> {
        Ok(match s {
            "off" | "none" | "f32" => QuantMode::Off,
            "int8" | "i8" => QuantMode::Int8,
            other => bail!("unknown quant mode {other:?} (off|int8)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            QuantMode::Off => "off",
            QuantMode::Int8 => "int8",
        }
    }

    pub fn is_int8(self) -> bool {
        self == QuantMode::Int8
    }
}

/// CLI-facing paged-KV knobs (`--kv-mem-mb`, `--kv-dtype`, `--kv-block`).
/// Handed to [`DecodeSession::new_paged`]; `mem_bytes == None` sizes the
/// pool to hold every session row at full context (paging without a
/// budget cap — still enables prefix sharing and reduced precision).
///
/// [`DecodeSession::new_paged`]: crate::runtime::backend::DecodeSession::new_paged
#[derive(Debug, Clone, Copy)]
pub struct KvCacheConfig {
    pub dtype: KvDtype,
    /// Tokens per block/page (clamped to `ctx` at pool construction).
    pub block_tokens: usize,
    /// Byte budget for the whole K+V block pool.
    pub mem_bytes: Option<usize>,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig { dtype: KvDtype::F32, block_tokens: 16, mem_bytes: None }
    }
}

impl KvCacheConfig {
    /// Set the byte budget from the CLI's MiB knob.
    pub fn with_mem_mb(mut self, mb: usize) -> KvCacheConfig {
        self.mem_bytes = Some(mb * 1024 * 1024);
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.block_tokens == 0 {
            bail!("kv block_tokens must be >= 1");
        }
        Ok(())
    }
}

/// One (config, normalizer) pair from the manifest, e.g. `paper_consmax`.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub key: String,
    pub vocab: usize,
    pub ctx: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub n_embd: usize,
    pub normalizer: String,
    pub beta_init: f64,
    pub gamma_init: f64,
    pub total_steps: usize,
    pub train_batch: usize,
    /// Canonical parameter flattening order shared with python.
    pub param_order: Vec<String>,
    /// name -> shape.
    pub param_shapes: BTreeMap<String, Vec<usize>>,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.n_embd / self.n_head
    }

    pub fn param_count(&self) -> usize {
        self.param_shapes
            .values()
            .map(|s| s.iter().product::<usize>())
            .sum()
    }

    pub fn shape_of(&self, name: &str) -> Result<&[usize]> {
        self.param_shapes
            .get(name)
            .map(Vec::as_slice)
            .with_context(|| format!("unknown param {name}"))
    }

    /// Construct a model configuration without a manifest, mirroring the
    /// named presets in `python/compile/model.py` (`TINY` / `PAPER`) and
    /// its `param_order` / parameter shapes exactly. This is what the
    /// native backend runs on when no `artifacts/` directory exists; when
    /// a manifest IS present the two sources agree by construction (both
    /// derive from the same python presets) and the manifest wins.
    pub fn builtin(config: &str, normalizer: &str) -> Result<ModelConfig> {
        // single source of truth for normalizer names: the Normalizer
        // registry (DESIGN.md §Normalizer seam)
        let norm = crate::runtime::backend::Normalizer::parse(normalizer)?;
        let (vocab, ctx, n_layer, n_head, n_embd, train_batch, total_steps) =
            match config {
                "tiny" => (256usize, 64usize, 2usize, 2usize, 64usize, 4usize, 200usize),
                "paper" => (256, 256, 6, 6, 384, 8, 2000),
                other => bail!("unknown builtin config {other:?} (tiny|paper)"),
            };
        let (l, h, d) = (n_layer, n_head, n_embd);
        let mut param_order: Vec<String> = [
            "wte", "wpe", "ln1_g", "ln1_b", "attn_qkv_w", "attn_qkv_b",
            "attn_proj_w", "attn_proj_b", "beta", "gamma", "ln2_g", "ln2_b",
            "mlp_fc_w", "mlp_fc_b", "mlp_proj_w", "mlp_proj_b", "lnf_g",
            "lnf_b",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let mut shapes: Vec<(&str, Vec<usize>)> = vec![
            ("wte", vec![vocab, d]),
            ("wpe", vec![ctx, d]),
            ("ln1_g", vec![l, d]),
            ("ln1_b", vec![l, d]),
            ("attn_qkv_w", vec![l, d, 3 * d]),
            ("attn_qkv_b", vec![l, 3 * d]),
            ("attn_proj_w", vec![l, d, d]),
            ("attn_proj_b", vec![l, d]),
            ("beta", vec![l, h]),
            ("gamma", vec![l, h]),
            ("ln2_g", vec![l, d]),
            ("ln2_b", vec![l, d]),
            ("mlp_fc_w", vec![l, d, 4 * d]),
            ("mlp_fc_b", vec![l, 4 * d]),
            ("mlp_proj_w", vec![l, 4 * d, d]),
            ("mlp_proj_b", vec![l, d]),
            ("lnf_g", vec![d]),
            ("lnf_b", vec![d]),
        ];
        // zoo members with extra learnables (e.g. ssmax's per-head
        // scale) append them after the shared 18-tensor schema
        for extra in norm.extra_params() {
            param_order.push(extra.to_string());
            shapes.push((extra, vec![l, h]));
        }
        let param_shapes: BTreeMap<String, Vec<usize>> = shapes
            .into_iter()
            .map(|(n, s)| (n.to_string(), s))
            .collect();
        Ok(ModelConfig {
            key: format!("{config}_{normalizer}"),
            vocab,
            ctx,
            n_layer,
            n_head,
            n_embd,
            normalizer: normalizer.to_string(),
            beta_init: 2.5,
            gamma_init: 100.0,
            total_steps,
            train_batch,
            param_order,
            param_shapes,
        })
    }

    fn from_json(key: &str, v: &Json) -> Result<ModelConfig> {
        let req_usize = |k: &str| -> Result<usize> {
            v.get(k)
                .as_usize()
                .with_context(|| format!("config {key}: missing/invalid {k}"))
        };
        let mut param_shapes = BTreeMap::new();
        let shapes = v
            .get("param_shapes")
            .as_obj()
            .context("missing param_shapes")?;
        for (name, shape) in shapes {
            param_shapes.insert(
                name.clone(),
                shape
                    .to_usize_vec()
                    .with_context(|| format!("bad shape for {name}"))?,
            );
        }
        Ok(ModelConfig {
            key: key.to_string(),
            vocab: req_usize("vocab")?,
            ctx: req_usize("ctx")?,
            n_layer: req_usize("n_layer")?,
            n_head: req_usize("n_head")?,
            n_embd: req_usize("n_embd")?,
            normalizer: v
                .get("normalizer")
                .as_str()
                .context("missing normalizer")?
                .to_string(),
            beta_init: v.get("beta_init").as_f64().unwrap_or(2.5),
            gamma_init: v.get("gamma_init").as_f64().unwrap_or(100.0),
            total_steps: v.get("total_steps").as_usize().unwrap_or(2000),
            train_batch: req_usize("train_batch")?,
            param_order: v
                .get("param_order")
                .as_arr()
                .context("missing param_order")?
                .iter()
                .map(|s| s.as_str().unwrap_or_default().to_string())
                .collect(),
            param_shapes,
        })
    }
}

/// I/O spec of one AOT entry point.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            shape: v.get("shape").to_usize_vec().context("bad shape")?,
            dtype: v
                .get("dtype")
                .as_str()
                .context("bad dtype")?
                .to_string(),
        })
    }
}

/// One AOT artifact (an HLO-text executable-to-be).
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub doc: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, EntrySpec>,
    pub configs: BTreeMap<String, ModelConfig>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;
        if v.get("format").as_str() != Some("hlo-text-v1") {
            bail!("unsupported manifest format {:?}", v.get("format"));
        }

        let mut entries = BTreeMap::new();
        for (name, e) in v.get("entries").as_obj().context("entries")? {
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file: dir.join(e.get("file").as_str().context("file")?),
                    doc: e.get("doc").as_str().unwrap_or("").to_string(),
                    inputs: e
                        .get("inputs")
                        .as_arr()
                        .context("inputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: e
                        .get("outputs")
                        .as_arr()
                        .context("outputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                },
            );
        }

        let mut configs = BTreeMap::new();
        for (key, c) in v.get("configs").as_obj().context("configs")? {
            configs.insert(key.clone(), ModelConfig::from_json(key, c)?);
        }
        Ok(Manifest { dir, entries, configs })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .with_context(|| format!("no artifact entry {name:?} (run `make artifacts`)"))
    }

    pub fn config(&self, key: &str) -> Result<&ModelConfig> {
        self.configs
            .get(key)
            .with_context(|| format!("no model config {key:?}"))
    }
}

/// Coordinator-level run configuration (CLI-facing).
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub artifacts_dir: PathBuf,
    pub config: String,
    pub normalizer: String,
    pub steps: usize,
    pub seed: u64,
    pub log_every: usize,
    pub eval_every: usize,
    pub out_dir: PathBuf,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            config: "tiny".into(),
            normalizer: "consmax".into(),
            steps: 200,
            seed: 0,
            log_every: 10,
            eval_every: 50,
            out_dir: PathBuf::from("runs"),
        }
    }
}

impl RunConfig {
    pub fn model_key(&self) -> String {
        format!("{}_{}", self.config, self.normalizer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal_manifest_json() -> String {
        r#"{
          "format": "hlo-text-v1",
          "entries": {
            "tiny_consmax_eval_step": {
              "file": "tiny_consmax_eval_step.hlo.txt",
              "doc": "d",
              "inputs": [{"shape": [2, 3], "dtype": "float32"}],
              "outputs": [{"shape": [], "dtype": "float32"}]
            }
          },
          "configs": {
            "tiny_consmax": {
              "vocab": 256, "ctx": 64, "n_layer": 2, "n_head": 2,
              "n_embd": 64, "normalizer": "consmax", "beta_init": 2.5,
              "gamma_init": 100.0, "total_steps": 200, "train_batch": 4,
              "param_order": ["wte", "beta"],
              "param_shapes": {"wte": [256, 64], "beta": [2, 2]}
            }
          }
        }"#
        .to_string()
    }

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), minimal_manifest_json())
            .unwrap();
    }

    #[test]
    fn loads_minimal_manifest() {
        let dir = std::env::temp_dir().join("consmax_test_manifest_1");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let c = m.config("tiny_consmax").unwrap();
        assert_eq!(c.n_embd, 64);
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.param_count(), 256 * 64 + 4);
        let e = m.entry("tiny_consmax_eval_step").unwrap();
        assert_eq!(e.inputs[0].elems(), 6);
        assert_eq!(e.outputs[0].shape, Vec::<usize>::new());
    }

    #[test]
    fn missing_entry_errors_helpfully() {
        let dir = std::env::temp_dir().join("consmax_test_manifest_2");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let err = m.entry("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn rejects_unknown_format() {
        let dir = std::env::temp_dir().join("consmax_test_manifest_3");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": "other", "entries": {}, "configs": {}}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn run_config_key() {
        let rc = RunConfig::default();
        assert_eq!(rc.model_key(), "tiny_consmax");
    }

    #[test]
    fn builtin_tiny_matches_python_preset() {
        let c = ModelConfig::builtin("tiny", "consmax").unwrap();
        assert_eq!(c.key, "tiny_consmax");
        assert_eq!((c.vocab, c.ctx, c.n_layer, c.n_head, c.n_embd), (256, 64, 2, 2, 64));
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.param_order.len(), 18);
        assert_eq!(c.shape_of("attn_qkv_w").unwrap(), &[2, 64, 192]);
        assert_eq!(c.shape_of("beta").unwrap(), &[2, 2]);
        assert_eq!(c.shape_of("lnf_g").unwrap(), &[64]);
        // param count: same formula as the manifest-backed config
        assert!(c.param_count() > 100_000, "{}", c.param_count());
    }

    #[test]
    fn builtin_paper_is_the_6l_model() {
        let c = ModelConfig::builtin("paper", "softmax").unwrap();
        assert_eq!((c.n_layer, c.n_head, c.n_embd, c.ctx), (6, 6, 384, 256));
        assert_eq!(c.train_batch, 8);
        assert_eq!(c.shape_of("mlp_fc_w").unwrap(), &[6, 384, 1536]);
    }

    #[test]
    fn builtin_rejects_unknowns() {
        assert!(ModelConfig::builtin("huge", "consmax").is_err());
        assert!(ModelConfig::builtin("tiny", "sparsemax").is_err());
    }

    #[test]
    fn builtin_accepts_the_full_normalizer_zoo() {
        for norm in crate::runtime::backend::Normalizer::NAMES {
            let c = ModelConfig::builtin("tiny", norm).unwrap();
            assert_eq!(c.normalizer, norm);
        }
    }

    #[test]
    fn builtin_ssmax_appends_its_scale_param() {
        let c = ModelConfig::builtin("tiny", "ssmax").unwrap();
        assert_eq!(c.param_order.len(), 19);
        assert_eq!(c.param_order.last().unwrap(), "ssmax_s");
        assert_eq!(c.shape_of("ssmax_s").unwrap(), &[2, 2]);
        // the shared 18-tensor schema is untouched for the rest of the zoo
        for norm in ["softmax", "consmax", "softermax", "consmax-v2"] {
            let c = ModelConfig::builtin("tiny", norm).unwrap();
            assert_eq!(c.param_order.len(), 18, "{norm}");
        }
    }

    #[test]
    fn kv_dtype_parses_and_roundtrips() {
        assert_eq!(KvDtype::parse("f32").unwrap(), KvDtype::F32);
        assert_eq!(KvDtype::parse("fp16").unwrap(), KvDtype::F16);
        assert_eq!(KvDtype::parse("bf16").unwrap(), KvDtype::Bf16);
        assert_eq!(KvDtype::parse("int8").unwrap(), KvDtype::Int8);
        assert!(KvDtype::parse("int4").is_err());
        assert_eq!(KvDtype::F32.bytes_per_elem(), 4);
        assert_eq!(KvDtype::F16.bytes_per_elem(), 2);
        assert_eq!(KvDtype::Int8.bytes_per_elem(), 1);
        // f32 round trip is the identity, bit for bit
        let x = 0.1234567f32;
        assert_eq!(KvDtype::F32.roundtrip(x).to_bits(), x.to_bits());
        // f16/bf16/int8 round trips are idempotent (storage-stable)
        for d in [KvDtype::F16, KvDtype::Bf16, KvDtype::Int8] {
            let once = d.roundtrip(x);
            assert_eq!(d.roundtrip(once).to_bits(), once.to_bits(), "{d:?}");
        }
    }

    #[test]
    fn int8_vector_roundtrip_is_idempotent_and_bounded() {
        // per-vector quantization: one pow2 scale per head_dim vector,
        // |x - roundtrip(x)| <= scale/2, and re-roundtripping the
        // already-quantized vector reproduces the same bits (so paged
        // decode staging == pool storage).
        let mut v: Vec<f32> =
            (0..32).map(|i| ((i as f32) - 11.5) * 0.37).collect();
        let orig = v.clone();
        KvDtype::Int8.roundtrip_vec(&mut v);
        let scale = crate::quant::kv_vec_scale(&orig);
        for (a, b) in orig.iter().zip(&v) {
            assert!((a - b).abs() <= scale / 2.0 + 1e-12, "{a} vs {b}");
        }
        let once = v.clone();
        KvDtype::Int8.roundtrip_vec(&mut v);
        for (a, b) in once.iter().zip(&v) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn quant_mode_parses() {
        assert_eq!(QuantMode::parse("off").unwrap(), QuantMode::Off);
        assert_eq!(QuantMode::parse("int8").unwrap(), QuantMode::Int8);
        assert!(QuantMode::parse("int4").is_err());
        assert_eq!(QuantMode::default(), QuantMode::Off);
        assert_eq!(QuantMode::Int8.name(), "int8");
        assert!(QuantMode::Int8.is_int8());
        assert!(!QuantMode::Off.is_int8());
    }

    #[test]
    fn kv_cache_config_knobs() {
        let kv = KvCacheConfig::default();
        assert_eq!(kv.dtype, KvDtype::F32);
        assert_eq!(kv.block_tokens, 16);
        assert!(kv.mem_bytes.is_none());
        assert!(kv.validate().is_ok());
        let kv = kv.with_mem_mb(3);
        assert_eq!(kv.mem_bytes, Some(3 * 1024 * 1024));
        let bad = KvCacheConfig { block_tokens: 0, ..KvCacheConfig::default() };
        assert!(bad.validate().is_err());
    }
}
