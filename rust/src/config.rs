//! Run configuration: a typed view over the artifact manifest plus the
//! coordinator's own knobs. Everything the Rust side needs to know about
//! a model variant comes from `artifacts/manifest.json` (written by
//! `python/compile/aot.py`), keeping the two languages in lock-step.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One (config, normalizer) pair from the manifest, e.g. `paper_consmax`.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub key: String,
    pub vocab: usize,
    pub ctx: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub n_embd: usize,
    pub normalizer: String,
    pub beta_init: f64,
    pub gamma_init: f64,
    pub total_steps: usize,
    pub train_batch: usize,
    /// Canonical parameter flattening order shared with python.
    pub param_order: Vec<String>,
    /// name -> shape.
    pub param_shapes: BTreeMap<String, Vec<usize>>,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.n_embd / self.n_head
    }

    pub fn param_count(&self) -> usize {
        self.param_shapes
            .values()
            .map(|s| s.iter().product::<usize>())
            .sum()
    }

    pub fn shape_of(&self, name: &str) -> Result<&[usize]> {
        self.param_shapes
            .get(name)
            .map(Vec::as_slice)
            .with_context(|| format!("unknown param {name}"))
    }

    fn from_json(key: &str, v: &Json) -> Result<ModelConfig> {
        let req_usize = |k: &str| -> Result<usize> {
            v.get(k)
                .as_usize()
                .with_context(|| format!("config {key}: missing/invalid {k}"))
        };
        let mut param_shapes = BTreeMap::new();
        let shapes = v
            .get("param_shapes")
            .as_obj()
            .context("missing param_shapes")?;
        for (name, shape) in shapes {
            param_shapes.insert(
                name.clone(),
                shape
                    .to_usize_vec()
                    .with_context(|| format!("bad shape for {name}"))?,
            );
        }
        Ok(ModelConfig {
            key: key.to_string(),
            vocab: req_usize("vocab")?,
            ctx: req_usize("ctx")?,
            n_layer: req_usize("n_layer")?,
            n_head: req_usize("n_head")?,
            n_embd: req_usize("n_embd")?,
            normalizer: v
                .get("normalizer")
                .as_str()
                .context("missing normalizer")?
                .to_string(),
            beta_init: v.get("beta_init").as_f64().unwrap_or(2.5),
            gamma_init: v.get("gamma_init").as_f64().unwrap_or(100.0),
            total_steps: v.get("total_steps").as_usize().unwrap_or(2000),
            train_batch: req_usize("train_batch")?,
            param_order: v
                .get("param_order")
                .as_arr()
                .context("missing param_order")?
                .iter()
                .map(|s| s.as_str().unwrap_or_default().to_string())
                .collect(),
            param_shapes,
        })
    }
}

/// I/O spec of one AOT entry point.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            shape: v.get("shape").to_usize_vec().context("bad shape")?,
            dtype: v
                .get("dtype")
                .as_str()
                .context("bad dtype")?
                .to_string(),
        })
    }
}

/// One AOT artifact (an HLO-text executable-to-be).
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub doc: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, EntrySpec>,
    pub configs: BTreeMap<String, ModelConfig>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;
        if v.get("format").as_str() != Some("hlo-text-v1") {
            bail!("unsupported manifest format {:?}", v.get("format"));
        }

        let mut entries = BTreeMap::new();
        for (name, e) in v.get("entries").as_obj().context("entries")? {
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file: dir.join(e.get("file").as_str().context("file")?),
                    doc: e.get("doc").as_str().unwrap_or("").to_string(),
                    inputs: e
                        .get("inputs")
                        .as_arr()
                        .context("inputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: e
                        .get("outputs")
                        .as_arr()
                        .context("outputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                },
            );
        }

        let mut configs = BTreeMap::new();
        for (key, c) in v.get("configs").as_obj().context("configs")? {
            configs.insert(key.clone(), ModelConfig::from_json(key, c)?);
        }
        Ok(Manifest { dir, entries, configs })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .with_context(|| format!("no artifact entry {name:?} (run `make artifacts`)"))
    }

    pub fn config(&self, key: &str) -> Result<&ModelConfig> {
        self.configs
            .get(key)
            .with_context(|| format!("no model config {key:?}"))
    }
}

/// Coordinator-level run configuration (CLI-facing).
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub artifacts_dir: PathBuf,
    pub config: String,
    pub normalizer: String,
    pub steps: usize,
    pub seed: u64,
    pub log_every: usize,
    pub eval_every: usize,
    pub out_dir: PathBuf,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            config: "tiny".into(),
            normalizer: "consmax".into(),
            steps: 200,
            seed: 0,
            log_every: 10,
            eval_every: 50,
            out_dir: PathBuf::from("runs"),
        }
    }
}

impl RunConfig {
    pub fn model_key(&self) -> String {
        format!("{}_{}", self.config, self.normalizer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal_manifest_json() -> String {
        r#"{
          "format": "hlo-text-v1",
          "entries": {
            "tiny_consmax_eval_step": {
              "file": "tiny_consmax_eval_step.hlo.txt",
              "doc": "d",
              "inputs": [{"shape": [2, 3], "dtype": "float32"}],
              "outputs": [{"shape": [], "dtype": "float32"}]
            }
          },
          "configs": {
            "tiny_consmax": {
              "vocab": 256, "ctx": 64, "n_layer": 2, "n_head": 2,
              "n_embd": 64, "normalizer": "consmax", "beta_init": 2.5,
              "gamma_init": 100.0, "total_steps": 200, "train_batch": 4,
              "param_order": ["wte", "beta"],
              "param_shapes": {"wte": [256, 64], "beta": [2, 2]}
            }
          }
        }"#
        .to_string()
    }

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), minimal_manifest_json())
            .unwrap();
    }

    #[test]
    fn loads_minimal_manifest() {
        let dir = std::env::temp_dir().join("consmax_test_manifest_1");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let c = m.config("tiny_consmax").unwrap();
        assert_eq!(c.n_embd, 64);
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.param_count(), 256 * 64 + 4);
        let e = m.entry("tiny_consmax_eval_step").unwrap();
        assert_eq!(e.inputs[0].elems(), 6);
        assert_eq!(e.outputs[0].shape, Vec::<usize>::new());
    }

    #[test]
    fn missing_entry_errors_helpfully() {
        let dir = std::env::temp_dir().join("consmax_test_manifest_2");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let err = m.entry("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn rejects_unknown_format() {
        let dir = std::env::temp_dir().join("consmax_test_manifest_3");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": "other", "entries": {}, "configs": {}}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn run_config_key() {
        let rc = RunConfig::default();
        assert_eq!(rc.model_key(), "tiny_consmax");
    }
}
