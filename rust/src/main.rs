//! `consmax` — the coordinator CLI.
//!
//! ```text
//! consmax train        train a GPT variant (native backward, or AOT pjrt)
//! consmax compare      Fig 6: train softmax vs consmax on identical data
//! consmax eval         validation loss/perplexity of a checkpoint
//! consmax sweep-init   Fig 8: β/γ initialization grid (pjrt)
//! consmax generate     sample text from a checkpoint
//! consmax serve-demo   batched generation service + latency stats
//! consmax serve-net    hardened TCP/HTTP serving front end (drains on SIGTERM)
//! consmax hw-report    Table I + savings ratios (synthesis estimator)
//! consmax sim          Fig 5: pipeline schedules, utilization, savings
//! consmax info         backend, op and model-config summary
//! ```
//!
//! Backend selection (`--backend native|pjrt|auto`): everything except
//! `sweep-init` runs end-to-end on the pure-Rust native backend — no
//! Python, no PJRT, no `artifacts/`. `consmax train --backend native`
//! uses the hand-derived backward + AdamW in
//! `runtime::backend::train` / `coordinator::trainer` (DESIGN.md
//! §Training seam); `--backend pjrt` keeps the fused AOT train step
//! (`--features pjrt` + `make artifacts`).

use std::path::PathBuf;

use anyhow::{bail, Result};

use consmax::config::{KvCacheConfig, KvDtype, ModelConfig, QuantMode};
#[cfg(feature = "pjrt")]
use consmax::coordinator::{best_point, sweep_init, SweepOptions, Trainer};
use consmax::coordinator::{
    DecodeMode, EngineAdapter, GenRequest, Generator, NativeTrainer,
    ParamStore, Server, SpecConfig, TrainOptions,
};
use consmax::data::{BatchSampler, ByteTokenizer, Corpus};
use consmax::hw::{savings, table1, EdaFlow};
use consmax::metrics::perplexity;
use consmax::runtime::backend::{
    create_backend, Backend, BackendChoice, NativeModel, Normalizer,
};
#[cfg(feature = "pjrt")]
use consmax::runtime::Engine;
use consmax::sim::{simulate, NormKind, Schedule, Workload};
use consmax::util::bench::print_table;
use consmax::util::cli::{render_help, Args, Spec};
use consmax::util::rng::Pcg32;

fn specs() -> Vec<Spec> {
    vec![
        Spec::opt_default("backend", "auto", "execution backend (native|pjrt|auto)"),
        Spec::opt_default("decode", "kv", "native decode engine (kv|recompute)"),
        Spec::opt("threads", "native worker threads (default: CONSMAX_THREADS or all cores)"),
        Spec::opt("simd", "SIMD microkernels, auto|off (default: CONSMAX_SIMD or auto)"),
        Spec::opt_default("artifacts", "artifacts", "artifacts directory (pjrt)"),
        Spec::opt_default("config", "tiny", "model config (tiny|paper)"),
        Spec::opt_default("normalizer", "consmax", Normalizer::HELP),
        Spec::opt_default("steps", "100", "training steps"),
        Spec::opt_default("seed", "0", "RNG seed"),
        Spec::opt_default("corpus", "tiny", "tiny|synthetic|<path>"),
        Spec::opt_default("corpus-words", "100000", "synthetic corpus size"),
        Spec::opt_default("log-every", "10", "metric logging stride"),
        Spec::opt_default("eval-every", "0", "validation stride (0 = off)"),
        Spec::opt("checkpoint", "checkpoint path to save/load"),
        Spec::opt_default("out", "runs", "output directory for metrics"),
        Spec::opt_default("prompt", "The attention ", "generation prompt"),
        Spec::opt_default("max-new", "64", "tokens to generate"),
        Spec::opt_default("temperature", "0", "sampling temperature (0=greedy)"),
        Spec::opt_default("requests", "16", "serve-demo request count"),
        Spec::opt_default(
            "sched",
            "continuous",
            "serve-demo scheduler (continuous|static); continuous needs \
             the native KV engine and falls back to static elsewhere",
        ),
        Spec::opt(
            "max-batch",
            "serve-demo: serving slot cap (default: backend max; paged \
             pools may raise it past the dense engine cap)",
        ),
        Spec::opt(
            "kv-mem-mb",
            "serve-demo: paged KV-cache byte budget in MiB — the real \
             capacity limit of the continuous scheduler (implies paging)",
        ),
        Spec::opt(
            "kv-dtype",
            "serve-demo: paged KV storage precision, f32|f16|bf16|int8 \
             (implies paging; f16/bf16 halve resident KV bytes, int8 \
             quarters them plus per-vector scales)",
        ),
        Spec::opt(
            "kv-block",
            "serve-demo: paged KV block size in tokens (default 16; \
             implies paging)",
        ),
        Spec::opt_default(
            "prefill-chunk",
            "off",
            "serve: chunked prefill — feed at most N prompt tokens per \
             scheduler tick instead of the whole prompt at admission, \
             interleaving long-prompt ingestion with resident decode \
             steps (off|N; continuous scheduler only)",
        ),
        Spec::opt_default(
            "spec",
            "off",
            "serve: self-speculative decoding (off|draft-k=K) — the \
             builtin tiny config drafts K greedy tokens per row and one \
             batched target step verifies them; greedy outputs stay \
             bit-identical to plain decode (continuous scheduler only)",
        ),
        Spec::opt_default(
            "listen",
            "127.0.0.1:8077",
            "serve-net: listen address (host:port; port 0 = ephemeral)",
        ),
        Spec::opt_default(
            "queue-cap",
            "64",
            "serve-net: bounded admission — shed with 429 + Retry-After \
             once this many requests are queued",
        ),
        Spec::opt_default(
            "deadline-ms",
            "0",
            "serve-net: default per-request deadline in ms (0 = none); \
             lapsed requests are dropped mid-flight, freeing their KV",
        ),
        Spec::opt_default(
            "drain-timeout-ms",
            "5000",
            "serve-net: how long a SIGTERM drain waits for residents \
             before cancelling them",
        ),
        Spec::opt_default(
            "heartbeat-ms",
            "500",
            "serve-net: idle-stream heartbeat interval",
        ),
        Spec::opt(
            "max-requests",
            "serve-net: drain after this many admission verdicts \
             (default: serve until SIGTERM)",
        ),
        Spec::opt_default("seq", "256", "sim/hw: context length"),
        Spec::opt_default("tokens", "1", "sim: tokens to process"),
        Spec::opt_default("norm", "consmax", "sim: normalizer"),
        Spec::opt_default("schedule", "auto", "sim: token|element|auto"),
        Spec::opt_default("flow", "proprietary", "hw: proprietary|opensource"),
        Spec::opt_default("warmup-steps", "30", "sweep: steps per grid point"),
        Spec::flag("no-trace-params", "disable beta/gamma series logging"),
        Spec::opt_default(
            "quant",
            "off",
            "serving quantization (off|int8): per-channel int8 weights + \
             LUT ConSmax tail on native eval/generate/serve-demo (eval \
             also reports the int8-vs-f32 loss delta); the AOT INT8 \
             normalizer path on pjrt eval",
        ),
        Spec::opt("beta0", "train: pin all beta inits to this value (Fig 8 winner)"),
        Spec::opt("gamma0", "train: pin all gamma inits to this value"),
        Spec::flag("help", "show help"),
    ]
}

fn main() {
    env_logger_lite();
    let args = match Args::parse(std::env::args().skip(1), &specs()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    // install the worker-pool size before any backend work runs:
    // --threads beats CONSMAX_THREADS beats available_parallelism
    match args.get_opt_usize("threads") {
        Ok(None) => {}
        Ok(Some(0)) => {
            eprintln!("error: --threads must be >= 1");
            std::process::exit(2);
        }
        Ok(Some(n)) => consmax::runtime::parallel::set_threads(n),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
    // install the SIMD level the same way: --simd beats CONSMAX_SIMD
    if let Some(s) = args.get("simd") {
        match consmax::runtime::backend::simd::Mode::parse(s) {
            Ok(m) => consmax::runtime::backend::simd::set_mode(m),
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        }
    }
    if args.has_flag("help") || args.subcommand.is_none() {
        print!(
            "{}",
            render_help(
                "consmax",
                "ConSmax paper reproduction coordinator",
                &[
                    ("train", "train a GPT variant (native backward or AOT pjrt)"),
                    ("compare", "Fig 6: softmax vs consmax on identical data"),
                    ("eval", "validation loss of a checkpoint"),
                    ("sweep-init", "Fig 8: beta/gamma initialization grid (pjrt)"),
                    ("generate", "sample text from a checkpoint"),
                    ("serve-demo", "batched generation + latency stats"),
                    ("serve-net", "hardened TCP/HTTP serving front end"),
                    ("hw-report", "Table I + savings ratios"),
                    ("sim", "Fig 5 pipeline simulation"),
                    ("info", "backend, op and model-config summary"),
                ],
                &specs()
            )
        );
        return;
    }
    let cmd = args.subcommand.clone().unwrap();
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

/// Minimal env_logger replacement: RUST_LOG=debug|warn|error, default info.
fn env_logger_lite() {
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let _ = log::set_logger(&LOGGER);
    let lvl = match std::env::var("RUST_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        _ => log::LevelFilter::Info,
    };
    log::set_max_level(lvl);
}

fn load_corpus(args: &Args) -> Result<Corpus> {
    Ok(match args.get("corpus").unwrap_or("tiny") {
        "tiny" => Corpus::tiny(),
        "synthetic" => Corpus::synthetic(
            args.get_usize("corpus-words", 100_000)?,
            args.get_u64("seed", 0)?,
        ),
        path => Corpus::from_file(std::path::Path::new(path))?,
    })
}

/// Should this invocation run on the PJRT engine? `auto` picks PJRT only
/// when it is compiled in AND artifacts exist, so a bare checkout always
/// lands on the native backend.
fn wants_pjrt(args: &Args) -> Result<bool> {
    match BackendChoice::parse(&args.get_string("backend", "auto"))? {
        BackendChoice::Native => Ok(false),
        BackendChoice::Pjrt => Ok(true),
        BackendChoice::Auto => Ok(consmax::runtime::backend::pjrt_available(
            std::path::Path::new(&args.get_string("artifacts", "artifacts")),
        )),
    }
}

#[cfg_attr(feature = "pjrt", allow(dead_code))]
fn pjrt_unavailable(what: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "{what} requires the PJRT backend: rebuild with `cargo build \
         --features pjrt`, run `make artifacts`, or pass --backend native \
         (see rust/README.md)"
    )
}

/// Build the (builtin) model config + parameter store for native runs.
fn native_model_setup(args: &Args) -> Result<(ModelConfig, ParamStore)> {
    let cfg = ModelConfig::builtin(
        &args.get_string("config", "tiny"),
        &args.get_string("normalizer", "consmax"),
    )?;
    let seed = args.get_u64("seed", 0)?;
    let store = match args.get("checkpoint") {
        Some(p) if std::path::Path::new(p).exists() => {
            ParamStore::load(std::path::Path::new(p), &cfg)?
        }
        Some(p) => bail!("checkpoint {p:?} not found"),
        None => {
            log::warn!("no checkpoint: using randomly initialized weights");
            ParamStore::init(&cfg, seed)?
        }
    };
    Ok((cfg, store))
}

#[cfg(feature = "pjrt")]
fn build_trainer<'e>(
    engine: &'e Engine,
    args: &Args,
    normalizer: &str,
) -> Result<Trainer<'e>> {
    let key = format!("{}_{normalizer}", args.get_string("config", "tiny"));
    let cfg = engine.manifest.config(&key)?.clone();
    let seed = args.get_u64("seed", 0)?;
    let corpus = load_corpus(args)?;
    let (train_text, val_text) = corpus.split();
    let tok = ByteTokenizer;
    let train =
        BatchSampler::new(tok.encode(train_text), cfg.train_batch, cfg.ctx, seed);
    let val =
        BatchSampler::new(tok.encode(val_text), cfg.train_batch, cfg.ctx, seed);

    let store = match args.get("checkpoint") {
        Some(p) if std::path::Path::new(p).exists() => {
            ParamStore::load(std::path::Path::new(p), &cfg)?
        }
        _ => ParamStore::init(&cfg, seed)?,
    };
    let mut store = store;
    if let (Some(b), Some(g)) = (args.get("beta0"), args.get("gamma0")) {
        let b: f32 = b.parse().map_err(|_| anyhow::anyhow!("bad beta0"))?;
        let g: f32 = g.parse().map_err(|_| anyhow::anyhow!("bad gamma0"))?;
        consmax::coordinator::sweep::pin_beta_gamma(&mut store, b, g);
        log::info!("pinned beta0={b} gamma0={g}");
    }
    log::info!(
        "model {key}: {} params, corpus {} ({} bytes)",
        store.param_count(),
        corpus.name,
        corpus.len_bytes()
    );
    Trainer::new(engine, &key, store, train, Some(val))
}

fn train_opts(args: &Args) -> Result<TrainOptions> {
    Ok(TrainOptions {
        steps: args.get_usize("steps", 100)?,
        log_every: args.get_usize("log-every", 10)?.max(1),
        eval_every: args.get_usize("eval-every", 0)?,
        eval_batches: 4,
        trace_params: !args.has_flag("no-trace-params"),
        checkpoint: args.get("checkpoint").map(PathBuf::from),
    })
}

// ---------------------------------------------------------------------------
// training-family subcommands (native backward everywhere; AOT on pjrt)
// ---------------------------------------------------------------------------

/// Build the native trainer: builtin config + in-tree corpus split +
/// init-or-load parameter store. Mirrors the PJRT `build_trainer`.
fn build_native_trainer(args: &Args, normalizer: &str) -> Result<NativeTrainer> {
    let cfg = ModelConfig::builtin(&args.get_string("config", "tiny"), normalizer)?;
    let seed = args.get_u64("seed", 0)?;
    let corpus = load_corpus(args)?;
    let (train_text, val_text) = corpus.split();
    let tok = ByteTokenizer;
    let train =
        BatchSampler::new(tok.encode(train_text), cfg.train_batch, cfg.ctx, seed);
    let val =
        BatchSampler::new(tok.encode(val_text), cfg.train_batch, cfg.ctx, seed);
    let mut store = match args.get("checkpoint") {
        Some(p) if std::path::Path::new(p).exists() => {
            ParamStore::load(std::path::Path::new(p), &cfg)?
        }
        _ => ParamStore::init(&cfg, seed)?,
    };
    if let (Some(b), Some(g)) = (args.get("beta0"), args.get("gamma0")) {
        let b: f32 = b.parse().map_err(|_| anyhow::anyhow!("bad beta0"))?;
        let g: f32 = g.parse().map_err(|_| anyhow::anyhow!("bad gamma0"))?;
        store.pin_beta_gamma(b, g);
        log::info!("pinned beta0={b} gamma0={g}");
    }
    log::info!(
        "model {}: {} params, corpus {} ({} bytes)",
        cfg.key,
        store.param_count(),
        corpus.name,
        corpus.len_bytes()
    );
    Ok(NativeTrainer::new(cfg, store, train, Some(val)))
}

fn run_train_family(cmd: &str, args: &Args) -> Result<()> {
    if wants_pjrt(args)? {
        return run_train_family_pjrt(cmd, args);
    }
    match cmd {
        "train" => {
            let normalizer = args.get_string("normalizer", "consmax");
            let mut tr = build_native_trainer(args, &normalizer)?;
            let report = tr.train(&train_opts(args)?)?;
            let out = PathBuf::from(args.get_string("out", "runs"))
                .join(format!("{}_train.jsonl", tr.cfg.key));
            tr.metrics.save(&out)?;
            let first = tr
                .metrics
                .get("train_loss")
                .and_then(|s| s.points.first().map(|&(_, v)| v))
                .unwrap_or(report.final_loss);
            println!(
                "trained {} steps (native backward): loss {first:.4} -> {:.4} \
                 ({}), ppl {:.1}, {:.2} steps/s; metrics -> {}",
                report.steps,
                report.final_loss,
                if report.final_loss < first { "decreased" } else { "increased" },
                report.final_ppl,
                report.steps_per_s,
                out.display()
            );
            Ok(())
        }
        "compare" => {
            let mut rows = Vec::new();
            for norm in ["softmax", "consmax"] {
                let mut tr = build_native_trainer(args, norm)?;
                let mut opts = train_opts(args)?;
                opts.checkpoint = Some(
                    PathBuf::from(args.get_string("out", "runs"))
                        .join(format!("{}_compare.ckpt", tr.cfg.key)),
                );
                let report = tr.train(&opts)?;
                let val = tr.evaluate(4)?;
                let out = PathBuf::from(args.get_string("out", "runs"))
                    .join(format!("{}_compare.jsonl", tr.cfg.key));
                tr.metrics.save(&out)?;
                rows.push(vec![
                    norm.to_string(),
                    format!("{:.4}", report.final_loss),
                    format!("{:.1}", report.final_ppl),
                    format!("{:.4}", val),
                    format!("{:.1}", perplexity(val)),
                ]);
            }
            print_table(
                "Fig 6 reproduction: Softmax vs ConSmax (same data, same seed, \
                 native backward)",
                &["normalizer", "train loss", "train ppl", "val loss", "val ppl"],
                &rows,
            );
            let sm: f64 = rows[0][3].parse().unwrap();
            let cs: f64 = rows[1][3].parse().unwrap();
            println!(
                "\nConSmax val-loss gap vs Softmax: {:+.2}%",
                (cs - sm) / sm * 100.0
            );
            Ok(())
        }
        // the warmup grid drives many short runs through the fused AOT
        // step; it has no native leg yet
        "sweep-init" => Err(pjrt_unavailable("`consmax sweep-init` (AOT warmup grid)")),
        other => bail!("unknown training subcommand {other:?}"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn run_train_family_pjrt(cmd: &str, _args: &Args) -> Result<()> {
    Err(pjrt_unavailable(&format!("`consmax {cmd} --backend pjrt`")))
}

#[cfg(feature = "pjrt")]
fn run_train_family_pjrt(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "train" => {
            let engine = Engine::new(args.get_string("artifacts", "artifacts"))?;
            let normalizer = args.get_string("normalizer", "consmax");
            let mut tr = build_trainer(&engine, args, &normalizer)?;
            let report = tr.train(&train_opts(args)?)?;
            let out = PathBuf::from(args.get_string("out", "runs"))
                .join(format!("{}_train.jsonl", tr.cfg.key));
            tr.metrics.save(&out)?;
            println!(
                "trained {} steps: loss {:.4} (ppl {:.1}), {:.2} steps/s; metrics -> {}",
                report.steps,
                report.final_loss,
                report.final_ppl,
                report.steps_per_s,
                out.display()
            );
            Ok(())
        }
        "compare" => {
            let engine = Engine::new(args.get_string("artifacts", "artifacts"))?;
            let mut rows = Vec::new();
            for norm in ["softmax", "consmax"] {
                let mut tr = build_trainer(&engine, args, norm)?;
                let mut opts = train_opts(args)?;
                // keep per-normalizer checkpoints so deployment-form
                // (quantized) evaluation can reuse the trained weights
                opts.checkpoint = Some(
                    PathBuf::from(args.get_string("out", "runs"))
                        .join(format!("{}_compare.ckpt", tr.cfg.key)),
                );
                let report = tr.train(&opts)?;
                let val = tr.evaluate(4)?;
                let out = PathBuf::from(args.get_string("out", "runs"))
                    .join(format!("{}_compare.jsonl", tr.cfg.key));
                tr.metrics.save(&out)?;
                rows.push(vec![
                    norm.to_string(),
                    format!("{:.4}", report.final_loss),
                    format!("{:.1}", report.final_ppl),
                    format!("{:.4}", val),
                    format!("{:.1}", perplexity(val)),
                ]);
            }
            print_table(
                "Fig 6 reproduction: Softmax vs ConSmax (same data, same seed)",
                &["normalizer", "train loss", "train ppl", "val loss", "val ppl"],
                &rows,
            );
            let sm: f64 = rows[0][3].parse().unwrap();
            let cs: f64 = rows[1][3].parse().unwrap();
            println!(
                "\nConSmax val-loss gap vs Softmax: {:+.2}%",
                (cs - sm) / sm * 100.0
            );
            Ok(())
        }
        "sweep-init" => {
            let engine = Engine::new(args.get_string("artifacts", "artifacts"))?;
            let key = format!(
                "{}_{}",
                args.get_string("config", "tiny"),
                args.get_string("normalizer", "consmax")
            );
            let cfg = engine.manifest.config(&key)?.clone();
            let corpus = load_corpus(args)?;
            let (train_text, val_text) = corpus.split();
            let tok = ByteTokenizer;
            let opts = SweepOptions {
                warmup_steps: args.get_usize("warmup-steps", 30)?,
                seed: args.get_u64("seed", 0)?,
                ..SweepOptions::default()
            };
            let points = sweep_init(
                &engine,
                &cfg,
                &tok.encode(train_text),
                &tok.encode(val_text),
                &opts,
            )?;
            let rows: Vec<Vec<String>> = points
                .iter()
                .map(|p| {
                    vec![
                        format!("{:.2}", p.beta0),
                        format!("{:.0}", p.gamma0),
                        format!("{:.4}", p.final_train_loss),
                        format!("{:.4}", p.val_loss),
                        format!("{:.2}", perplexity(p.val_loss)),
                    ]
                })
                .collect();
            print_table(
                "Fig 8 reproduction: beta/gamma initialization sweep",
                &["beta0", "gamma0", "train loss", "val loss", "val ppl"],
                &rows,
            );
            if let Some(b) = best_point(&points) {
                println!(
                    "\nbest init: beta0={} gamma0={} (val loss {:.4})",
                    b.beta0, b.gamma0, b.val_loss
                );
            }
            Ok(())
        }
        other => bail!("unknown training subcommand {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// backend-pluggable subcommands
// ---------------------------------------------------------------------------

fn run_eval(args: &Args) -> Result<()> {
    if wants_pjrt(args)? {
        return run_eval_pjrt(args);
    }
    let quant = QuantMode::parse(&args.get_string("quant", "off"))?;
    let (cfg, store) = native_model_setup(args)?;
    let corpus = load_corpus(args)?;
    let (_, val_text) = corpus.split();
    let tok = ByteTokenizer;
    let val =
        BatchSampler::new(tok.encode(val_text), cfg.train_batch, cfg.ctx, 0);
    let batches = val.eval_batches(8);
    anyhow::ensure!(!batches.is_empty(), "validation stream too small");
    let eval_loss = |model: &NativeModel| -> Result<f64> {
        let mut total = 0.0;
        for (x, y) in &batches {
            total += model.loss(x, y, cfg.train_batch, cfg.ctx)?;
        }
        Ok(total / batches.len() as f64)
    };
    let model = NativeModel::from_params(&cfg, &store.order, &store.params)?;
    let loss = eval_loss(&model)?;
    if quant.is_int8() {
        // the same weights through the int8 serving path: per-channel
        // int8 projections + the LUT ConSmax tail. The printed delta is
        // the paper's "comparable accuracy" claim; benches/quant_gate.rs
        // turns it into a CI-enforced bound.
        let qmodel = NativeModel::from_params_quant(
            &cfg,
            &store.order,
            &store.params,
            quant,
        )?;
        let qloss = eval_loss(&qmodel)?;
        println!(
            "val loss {loss:.4}  ppl {:.2} (native, f32)",
            perplexity(loss)
        );
        println!(
            "val loss {qloss:.4}  ppl {:.2} (native, int8 weights + LUT tail)",
            perplexity(qloss)
        );
        println!("int8-vs-f32 loss delta {:+.4} nats", qloss - loss);
    } else {
        println!(
            "val loss {loss:.4}  ppl {:.2} (native backend)",
            perplexity(loss)
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn run_eval_pjrt(_args: &Args) -> Result<()> {
    Err(pjrt_unavailable("`consmax eval --backend pjrt`"))
}

#[cfg(feature = "pjrt")]
fn run_eval_pjrt(args: &Args) -> Result<()> {
    let engine = Engine::new(args.get_string("artifacts", "artifacts"))?;
    let normalizer = args.get_string("normalizer", "consmax");
    let quant = QuantMode::parse(&args.get_string("quant", "off"))?;
    let mut tr = build_trainer(&engine, args, &normalizer)?;
    let loss = if quant.is_int8() {
        tr.evaluate_quantized(8)?
    } else {
        tr.evaluate(8)?
    };
    let tag = if quant.is_int8() { " (INT8 hw normalizer)" } else { "" };
    println!("val loss {loss:.4}  ppl {:.2}{tag}", perplexity(loss));
    Ok(())
}

fn run_generate(args: &Args) -> Result<()> {
    if wants_pjrt(args)? {
        return run_generate_pjrt(args);
    }
    let (cfg, store) = native_model_setup(args)?;
    let mode = DecodeMode::parse(&args.get_string("decode", "kv"))?;
    let quant = QuantMode::parse(&args.get_string("quant", "off"))?;
    let mut g = Generator::native_quant(
        &cfg,
        &store,
        args.get_u64("seed", 0)?,
        mode,
        quant,
    )?;
    let prompt = args.get_string("prompt", "The attention ");
    let out = g.generate_batch(
        &[prompt.clone()],
        args.get_usize("max-new", 64)?,
        args.get_f64("temperature", 0.0)? as f32,
    )?;
    println!("{prompt}{}", out[0]);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn run_generate_pjrt(_args: &Args) -> Result<()> {
    Err(pjrt_unavailable("`consmax generate --backend pjrt`"))
}

#[cfg(feature = "pjrt")]
fn run_generate_pjrt(args: &Args) -> Result<()> {
    let engine = Engine::new(args.get_string("artifacts", "artifacts"))?;
    let normalizer = args.get_string("normalizer", "consmax");
    let key = format!("{}_{normalizer}", args.get_string("config", "tiny"));
    let cfg = engine.manifest.config(&key)?.clone();
    let store = match args.get("checkpoint") {
        Some(p) => ParamStore::load(std::path::Path::new(p), &cfg)?,
        None => {
            log::warn!("no checkpoint: generating from random weights");
            ParamStore::init(&cfg, args.get_u64("seed", 0)?)?
        }
    };
    let mut g = Generator::new(&engine, &store, args.get_u64("seed", 0)?)?;
    let prompt = args.get_string("prompt", "The attention ");
    let out = g.generate_batch(
        &[prompt.clone()],
        args.get_usize("max-new", 64)?,
        args.get_f64("temperature", 0.0)? as f32,
    )?;
    println!("{prompt}{}", out[0]);
    Ok(())
}

/// Build the paged-KV configuration from `--kv-mem-mb` / `--kv-dtype` /
/// `--kv-block`. Any one of them opts the continuous scheduler into the
/// paged block pool; none keeps the dense per-row layout.
fn kv_config_from_args(args: &Args) -> Result<Option<KvCacheConfig>> {
    let mem_mb = args.get_opt_usize("kv-mem-mb")?;
    let dtype = args.get("kv-dtype");
    let block = args.get_opt_usize("kv-block")?;
    if mem_mb.is_none() && dtype.is_none() && block.is_none() {
        return Ok(None);
    }
    let mut kv = KvCacheConfig::default();
    if let Some(d) = dtype {
        kv.dtype = KvDtype::parse(d)?;
    }
    if let Some(b) = block {
        kv.block_tokens = b;
    }
    if let Some(mb) = mem_mb {
        kv = kv.with_mem_mb(mb);
    }
    Ok(Some(kv))
}

/// Parse `--prefill-chunk off|N`. `None` keeps monolithic prefill.
fn prefill_chunk_from_args(args: &Args) -> Result<Option<usize>> {
    match args.get("prefill-chunk") {
        None | Some("off") => Ok(None),
        Some(s) => {
            let n: usize = s.parse().map_err(|_| {
                anyhow::anyhow!("--prefill-chunk expects off or a token count, got {s:?}")
            })?;
            if n == 0 {
                bail!("--prefill-chunk must be >= 1 (or off)");
            }
            Ok(Some(n))
        }
    }
}

/// Parse `--spec off|draft-k=K`. `None` keeps plain decode.
fn spec_from_args(args: &Args) -> Result<Option<usize>> {
    match args.get("spec") {
        None | Some("off") => Ok(None),
        Some(s) => {
            let Some(k) = s.strip_prefix("draft-k=") else {
                bail!("--spec expects off or draft-k=K, got {s:?}");
            };
            let k: usize = k.parse().map_err(|_| {
                anyhow::anyhow!("--spec draft-k expects an integer, got {k:?}")
            })?;
            if k == 0 {
                bail!("--spec draft-k must be >= 1");
            }
            Ok(Some(k))
        }
    }
}

/// Apply `--prefill-chunk` / `--spec` to a native continuous server.
///
/// The draft is always the builtin `tiny` config under the same
/// normalizer and runs unquantized: a `tiny` target reuses its own
/// weights (a self-draft, so every proposal verifies), any other target
/// drafts from seed-initialized tiny weights. Either way the target's
/// batched verification step keeps greedy outputs bit-identical to
/// plain decode.
fn configure_serving_features(
    server: &mut Server<'_>,
    args: &Args,
    cfg: &ModelConfig,
    store: &ParamStore,
) -> Result<()> {
    server.set_prefill_chunk(prefill_chunk_from_args(args)?)?;
    if let Some(draft_k) = spec_from_args(args)? {
        let normalizer = args.get_string("normalizer", "consmax");
        let draft_cfg = ModelConfig::builtin("tiny", &normalizer)?;
        let draft = if cfg.key == draft_cfg.key {
            NativeModel::from_params_quant(
                &draft_cfg,
                &store.order,
                &store.params,
                QuantMode::Off,
            )?
        } else {
            let dstore = ParamStore::init(&draft_cfg, args.get_u64("seed", 0)?)?;
            NativeModel::from_params_quant(
                &draft_cfg,
                &dstore.order,
                &dstore.params,
                QuantMode::Off,
            )?
        };
        server.set_spec(Some((SpecConfig { draft_k }, draft)))?;
    }
    Ok(())
}

/// One human-readable summary of the speculation/chunking telemetry,
/// shared by the serve-demo and serve-net drain reports.
fn print_serving_feature_stats(server: &Server<'_>) {
    let chunk = server.prefill_chunk();
    let spec = server.spec_config();
    if chunk.is_none() && spec.is_none() {
        return;
    }
    let st = server.stats();
    let chunk_s = chunk.map_or("off".to_string(), |c| c.to_string());
    let spec_s = spec.map_or("off".to_string(), |s| format!("draft-k={}", s.draft_k));
    let acc = if st.spec_proposed > 0 {
        format!(
            "{:.1}%",
            100.0 * st.spec_accepted as f64 / st.spec_proposed as f64
        )
    } else {
        "n/a".to_string()
    };
    println!(
        "serving features: prefill-chunk {chunk_s}, spec {spec_s} | \
         {} prefill-chunk feeds vs {} decode steps | draft proposed {} \
         accepted {} (acceptance {acc})",
        st.prefill_chunk_steps, st.decode_steps, st.spec_proposed, st.spec_accepted,
    );
}

fn serve_demo_over(mut server: Server<'_>, args: &Args) -> Result<()> {
    let n = args.get_usize("requests", 16)?;
    let max_new = args.get_usize("max-new", 32)?;
    let continuous = match args.get_string("sched", "continuous").as_str() {
        "continuous" if server.generator.supports_continuous() => true,
        "continuous" => {
            log::warn!(
                "continuous batching needs the native KV engine; \
                 falling back to the static scheduler"
            );
            false
        }
        "static" => false,
        other => bail!("unknown scheduler {other:?} (continuous|static)"),
    };
    if let Some(kv) = kv_config_from_args(args)? {
        // the paged pool backs the continuous slot pool only; applying
        // it to a static run would silently measure the dense layout
        if continuous {
            server.set_kv_config(Some(kv))?;
            log::info!(
                "paged KV pool: dtype {}, {} tokens/block{}",
                kv.dtype.name(),
                kv.block_tokens,
                kv.mem_bytes
                    .map(|b| format!(", budget {} MiB", b / (1024 * 1024)))
                    .unwrap_or_default()
            );
        } else {
            log::warn!(
                "--kv-mem-mb/--kv-dtype/--kv-block configure the \
                 continuous scheduler's paged pool; this static run \
                 keeps the dense KV layout"
            );
        }
    }
    if let Some(mb) = args.get_opt_usize("max-batch")? {
        server.set_max_batch(mb)?;
    }
    if !continuous
        && (server.prefill_chunk().is_some() || server.spec_config().is_some())
    {
        log::warn!(
            "--prefill-chunk/--spec drive the continuous scheduler; \
             this static run decodes without them"
        );
    }
    let mut rng = Pcg32::seeded(args.get_u64("seed", 0)?);
    let prompts = [
        "The transformer ", "Attention lets ", "Hardware that ",
        "During training ", "A lookup table ", "Long contexts ",
    ];
    for id in 0..n as u64 {
        server.submit(GenRequest {
            id,
            prompt: prompts[rng.below(prompts.len() as u64) as usize].into(),
            // short/long budget mix: this is the workload where the
            // schedulers actually differ (head-of-line blocking)
            max_new_tokens: if id % 4 == 0 { max_new } else { max_new / 4 + 1 },
            temperature: 0.8,
            stop: None,
            deadline_ms: None,
        });
    }
    let t0 = std::time::Instant::now();
    let responses = if continuous {
        server.run_continuous()?
    } else {
        server.run_to_completion()?
    };
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {} requests in {wall:.2}s ({:.1} tok/s) on the {} backend \
         ({} decode, quant {}, {} scheduler, {} threads, batch slots {})",
        responses.len(),
        server.tokens_out as f64 / wall,
        server.generator.backend_name(),
        server.generator.decode_name(),
        server.generator.quant_name(),
        if continuous { "continuous" } else { "static" },
        consmax::runtime::parallel::current_threads(),
        server.generator.max_batch(),
    );
    println!(
        "per-request completion p50 {:.0} ms p95 {:.0} ms | TTFT p50 {:.0} ms \
         p99 {:.0} ms | TPOT p50 {:.2} ms/tok",
        server.latencies.percentile(50.0).unwrap_or(0.0) / 1e3,
        server.latencies.percentile(95.0).unwrap_or(0.0) / 1e3,
        server.ttft.percentile(50.0).unwrap_or(0.0) / 1e3,
        server.ttft.percentile(99.0).unwrap_or(0.0) / 1e3,
        server.tpot.percentile(50.0).unwrap_or(0.0) / 1e3,
    );
    let st = server.stats();
    if st.kv_paged {
        println!(
            "paged KV pool: {} blocks x {} tokens ({} free at drain), \
             {} preemption(s)",
            st.kv_total_blocks,
            st.kv_block_tokens,
            st.kv_free_blocks,
            st.preemptions,
        );
    }
    print_serving_feature_stats(&server);
    if server.spec_config().is_some() {
        // per-request acceptance spread: a mixed workload can hide a
        // badly drafting request inside a healthy aggregate rate
        let mut rates: Vec<f64> = responses
            .iter()
            .filter(|r| r.spec_proposed > 0)
            .map(|r| r.spec_accepted as f64 / r.spec_proposed as f64)
            .collect();
        rates.sort_by(|a, b| a.total_cmp(b));
        if let (Some(lo), Some(hi)) = (rates.first(), rates.last()) {
            println!(
                "per-request acceptance: min {:.1}% median {:.1}% max {:.1}% \
                 ({} of {} requests drafted)",
                100.0 * lo,
                100.0 * rates[rates.len() / 2],
                100.0 * hi,
                rates.len(),
                responses.len(),
            );
        }
    }
    Ok(())
}

fn run_serve_demo(args: &Args) -> Result<()> {
    if wants_pjrt(args)? {
        return run_serve_demo_pjrt(args);
    }
    let (cfg, store) = native_model_setup(args)?;
    let mode = DecodeMode::parse(&args.get_string("decode", "kv"))?;
    let quant = QuantMode::parse(&args.get_string("quant", "off"))?;
    let gen = Generator::native_quant(&cfg, &store, 1, mode, quant)?;
    let mut server = Server::new(gen);
    configure_serving_features(&mut server, args, &cfg, &store)?;
    serve_demo_over(server, args)
}

#[cfg(not(feature = "pjrt"))]
fn run_serve_demo_pjrt(_args: &Args) -> Result<()> {
    Err(pjrt_unavailable("`consmax serve-demo --backend pjrt`"))
}

#[cfg(feature = "pjrt")]
fn run_serve_demo_pjrt(args: &Args) -> Result<()> {
    let engine = Engine::new(args.get_string("artifacts", "artifacts"))?;
    let normalizer = args.get_string("normalizer", "consmax");
    let key = format!("{}_{normalizer}", args.get_string("config", "tiny"));
    let cfg = engine.manifest.config(&key)?.clone();
    let store = match args.get("checkpoint") {
        Some(p) => ParamStore::load(std::path::Path::new(p), &cfg)?,
        None => ParamStore::init(&cfg, args.get_u64("seed", 0)?)?,
    };
    let gen = Generator::new(&engine, &store, 1)?;
    if prefill_chunk_from_args(args)?.is_some() || spec_from_args(args)?.is_some() {
        bail!(
            "--prefill-chunk/--spec need the native continuous scheduler \
             (run with --backend native)"
        );
    }
    serve_demo_over(Server::new(gen), args)
}

/// `consmax serve-net`: the hardened network front end over the
/// continuous scheduler. Runs until SIGTERM (drain) or `--max-requests`
/// admission verdicts, then flushes stats and exits.
fn run_serve_net(args: &Args) -> Result<()> {
    use consmax::runtime::serve_net::{self, FaultPlan, NetOptions};

    if wants_pjrt(args)? {
        bail!(
            "serve-net needs the native continuous scheduler \
             (run with --backend native)"
        );
    }
    let (cfg, store) = native_model_setup(args)?;
    let mode = DecodeMode::parse(&args.get_string("decode", "kv"))?;
    let quant = QuantMode::parse(&args.get_string("quant", "off"))?;
    let gen = Generator::native_quant(&cfg, &store, 1, mode, quant)?;
    let mut server = Server::new(gen);
    if let Some(kv) = kv_config_from_args(args)? {
        server.set_kv_config(Some(kv))?;
    }
    if let Some(mb) = args.get_opt_usize("max-batch")? {
        server.set_max_batch(mb)?;
    }
    configure_serving_features(&mut server, args, &cfg, &store)?;
    let queue_cap = args.get_usize("queue-cap", 64)?;
    let deadline_ms = args.get_u64("deadline-ms", 0)?;
    let mut engine = EngineAdapter::new(
        server,
        Some(queue_cap),
        None,
        (deadline_ms > 0).then_some(deadline_ms),
    )?;
    let opts = NetOptions {
        queue_cap,
        heartbeat_ms: args.get_u64("heartbeat-ms", 500)?.max(1),
        drain_timeout_ms: args.get_u64("drain-timeout-ms", 5_000)?,
        max_requests: args.get_opt_usize("max-requests")?.map(|n| n as u64),
        ..NetOptions::default()
    };
    let listener = serve_net::bind(&args.get_string("listen", "127.0.0.1:8077"))?;
    serve_net::install_sigterm_drain();
    println!(
        "serving on http://{} (POST /generate, GET /stats; SIGTERM drains)",
        listener.local_addr()?
    );
    let report =
        serve_net::serve(&mut engine, listener, &opts, &FaultPlan::default())?;
    let server = engine.into_server();
    println!(
        "drained ({}): admitted {} completed {} shed {} rejected {} \
         timed-out {} disconnects {} slow-readers {}",
        if report.drained_clean { "clean" } else { "forced" },
        report.admitted,
        report.completed,
        report.shed,
        report.rejected,
        report.timed_out,
        report.disconnects,
        report.slow_readers,
    );
    println!(
        "TTFT p50 {:.0} ms p99 {:.0} ms | TPOT p50 {:.2} ms/tok | \
         {} panics recovered, {} preemptions",
        server.ttft.percentile(50.0).unwrap_or(0.0) / 1e3,
        server.ttft.percentile(99.0).unwrap_or(0.0) / 1e3,
        server.tpot.percentile(50.0).unwrap_or(0.0) / 1e3,
        server.panics_recovered,
        server.preemptions,
    );
    let st = server.stats();
    if st.kv_paged {
        println!(
            "paged KV pool at drain: {} / {} blocks free",
            st.kv_free_blocks, st.kv_total_blocks
        );
    }
    print_serving_feature_stats(&server);
    Ok(())
}

fn run_info(args: &Args) -> Result<()> {
    let artifacts = args.get_string("artifacts", "artifacts");
    if wants_pjrt(args)? {
        return run_info_pjrt(args);
    }
    let backend = create_backend(
        BackendChoice::Native,
        std::path::Path::new(&artifacts),
    )?;
    println!("backend: {} — {}", backend.name(), backend.platform());
    println!(
        "simd: {} (select with --simd auto|off or CONSMAX_SIMD)",
        consmax::runtime::backend::simd::level().name()
    );
    println!("ops:");
    for op in backend.ops() {
        println!("  {op}");
    }
    println!("builtin configs (no artifacts needed):");
    for config in ["tiny", "paper"] {
        for norm in Normalizer::NAMES {
            let cfg = ModelConfig::builtin(config, norm)?;
            println!(
                "  {}: {}L/{}H/{}d ctx {} vocab {} ({} params)",
                cfg.key,
                cfg.n_layer,
                cfg.n_head,
                cfg.n_embd,
                cfg.ctx,
                cfg.vocab,
                cfg.param_count()
            );
        }
    }
    println!(
        "serving features: --prefill-chunk {}, --spec {}",
        prefill_chunk_from_args(args)?
            .map_or("off".to_string(), |c| c.to_string()),
        spec_from_args(args)?
            .map_or("off".to_string(), |k| format!("draft-k={k}")),
    );
    if !cfg!(feature = "pjrt") {
        println!("\npjrt engine not compiled (build with --features pjrt)");
    } else if std::path::Path::new(&artifacts).join("manifest.json").exists() {
        println!(
            "\npjrt engine compiled in; artifacts present at {artifacts:?} \
             (use --backend pjrt)"
        );
    } else {
        println!(
            "\npjrt engine compiled in; no artifacts at {artifacts:?} \
             (run `make artifacts`)"
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn run_info_pjrt(_args: &Args) -> Result<()> {
    Err(pjrt_unavailable("`consmax info --backend pjrt`"))
}

#[cfg(feature = "pjrt")]
fn run_info_pjrt(args: &Args) -> Result<()> {
    let engine = Engine::new(args.get_string("artifacts", "artifacts"))?;
    println!("backend: pjrt — platform {}", engine.platform());
    println!("configs:");
    for (key, cfg) in &engine.manifest.configs {
        println!(
            "  {key}: {}L/{}H/{}d ctx {} vocab {} ({} params)",
            cfg.n_layer, cfg.n_head, cfg.n_embd, cfg.ctx, cfg.vocab,
            cfg.param_count()
        );
    }
    println!("entries:");
    for (name, e) in &engine.manifest.entries {
        println!(
            "  {name}: {} in / {} out - {}",
            e.inputs.len(),
            e.outputs.len(),
            e.doc
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// dispatch
// ---------------------------------------------------------------------------

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "train" | "compare" | "sweep-init" => run_train_family(cmd, args),
        "eval" => run_eval(args),
        "generate" => run_generate(args),
        "serve-demo" => run_serve_demo(args),
        "serve-net" => run_serve_net(args),
        "info" => run_info(args),
        "hw-report" => {
            let flow = match args.get("flow").unwrap_or("proprietary") {
                "proprietary" => EdaFlow::Proprietary,
                "opensource" => EdaFlow::OpenSource,
                other => bail!("unknown flow {other:?}"),
            };
            let seq = args.get_usize("seq", 256)?;
            let rows = table1(flow, seq);
            let table: Vec<Vec<String>> = rows
                .iter()
                .map(|r| {
                    vec![
                        r.design.clone(),
                        r.corner.clone(),
                        format!("{:.0}", r.fmax_mhz),
                        format!("{:.5}", r.area_mm2),
                        format!("{:.3}", r.power_mw),
                        format!("{:.2}", r.opt_energy_pj),
                        format!("{:.0}", r.opt_energy_freq_mhz),
                    ]
                })
                .collect();
            print_table(
                &format!("Table I reproduction ({flow:?} flow, seq {seq})"),
                &["design", "corner", "Fmax MHz", "area mm2", "power mW",
                  "opt E pJ", "@ MHz"],
                &table,
            );
            let s_rows: Vec<Vec<String>> = savings(&rows)
                .iter()
                .map(|s| {
                    vec![
                        s.corner.clone(),
                        s.vs.clone(),
                        format!("{:.2}x", s.power_ratio),
                        format!("{:.2}x", s.area_ratio),
                    ]
                })
                .collect();
            print_table(
                "ConSmax savings",
                &["corner", "vs", "power", "area"],
                &s_rows,
            );
            Ok(())
        }
        "sim" => {
            let seq = args.get_usize("seq", 256)?;
            let tokens = args.get_usize("tokens", 1)?;
            let norm = match args.get("norm").unwrap_or("consmax") {
                "softmax" => NormKind::Softmax,
                "softermax" => NormKind::Softermax,
                "consmax" => NormKind::ConSmax,
                "partial" => NormKind::PartialSoftmax { chunks: 8 },
                other => bail!("unknown normalizer {other:?}"),
            };
            let schedule = match args.get("schedule").unwrap_or("auto") {
                "token" => Schedule::TokenPipeline,
                "element" => Schedule::ElementWise,
                "auto" => {
                    if norm.is_streaming() {
                        Schedule::ElementWise
                    } else {
                        Schedule::TokenPipeline
                    }
                }
                other => bail!("unknown schedule {other:?}"),
            };
            let w = Workload { tokens, ..Workload::paper_generation(seq) };
            let r = simulate(&w, norm, schedule);
            println!(
                "{} / {:?}: {} cycles, utilization {:.1}% \
                 (QK busy {}, norm busy {}, PV busy {})",
                norm.name(),
                schedule,
                r.total_cycles,
                r.utilization() * 100.0,
                r.qk.busy_cycles,
                r.norm_unit.busy_cycles,
                r.pv.busy_cycles
            );
            let base = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
            println!(
                "vs Softmax token-pipeline: {:.2}x speedup ({:.1}% time saving)",
                r.speedup_over(&base),
                (1.0 - r.total_cycles as f64 / base.total_cycles as f64) * 100.0
            );
            Ok(())
        }
        "report" => {
            // render run metrics (Fig 6/7 style) from runs/*.jsonl
            use consmax::coordinator::{report_compare, report_run};
            match args.positional.len() {
                1 => print!("{}", report_run(std::path::Path::new(&args.positional[0]))?),
                2 => print!(
                    "{}",
                    report_compare(
                        std::path::Path::new(&args.positional[0]),
                        std::path::Path::new(&args.positional[1])
                    )?
                ),
                _ => bail!("usage: consmax report <run.jsonl> [other.jsonl]"),
            }
            Ok(())
        }
        "rtl-gen" => {
            // emit the synthesizable Verilog bundle (paper §IV prototype)
            let dir = PathBuf::from(args.get_string("out", "runs")).join("rtl");
            let scale = 1.0 / 16.0;
            let bundle = consmax::hw::rtl::RtlBundle::generate(scale);
            bundle.write_to(&dir)?;
            for (name, text) in &bundle.files {
                println!(
                    "wrote {} ({} lines)",
                    dir.join(name).display(),
                    text.lines().count()
                );
            }
            println!(
                "\nROM image is bit-identical to quant::BitSplitLut (scale {scale}); \
                 simulate with any Verilog simulator:\n  iverilog -o tb {}/*.v && ./tb",
                dir.display()
            );
            Ok(())
        }
        "accel-report" => {
            // end-to-end accelerator integration (paper §IV-B)
            use consmax::sim::{compare_designs, AttentionConfig};
            let cfg = match args.get("config").unwrap_or("tiny") {
                "paper" | "tiny" => AttentionConfig::paper_gpt(),
                "gpt2" => AttentionConfig::gpt2_small_1k(),
                other => bail!("unknown accel config {other:?}"),
            };
            let rows: Vec<Vec<String>> = compare_designs(
                &cfg,
                consmax::hw::TechNode::Fin16,
                EdaFlow::Proprietary,
                500.0,
            )
            .iter()
            .map(|r| {
                vec![
                    r.design.clone(),
                    format!("{:.1}", r.token_latency_us),
                    format!("{:.2}", r.norm_energy_nj),
                    format!("{:.2}", r.tensorcore_energy_nj),
                    format!("{:.2}", r.stall_leakage_nj),
                    format!("{:.0}%", r.utilization * 100.0),
                ]
            })
            .collect();
            print_table(
                &format!(
                    "Accelerator integration: per-token attention cost \
                     ({}L/{}H/hd{} @ seq {}, 16nm, 500 MHz)",
                    cfg.n_layer, cfg.n_head, cfg.head_dim, cfg.seq
                ),
                &["normalizer", "latency us", "norm nJ", "tensorcore nJ",
                  "stall-leak nJ", "util"],
                &rows,
            );
            Ok(())
        }
        other => bail!("unknown command {other:?}; run with --help"),
    }
}
