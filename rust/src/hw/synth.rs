//! Synthesis estimator: netlist × technology profile → area / Fmax /
//! power / energy-per-op, plus the voltage–frequency energy sweep that
//! produces the U-curves of Fig 10.

use std::collections::BTreeMap;

use super::component::Kind;
use super::designs::UnitDesign;
use super::tech::TechProfile;

/// Post-"synthesis" figures for one design at one corner.
#[derive(Debug, Clone)]
pub struct SynthReport {
    pub design: String,
    pub corner: String,
    pub area_mm2: f64,
    pub fmax_mhz: f64,
    /// Dynamic + leakage power at frequency `f_mhz` and the matching
    /// minimum voltage.
    pub energy_pj_per_elem_nominal: f64,
    pub leakage_mw_nominal: f64,
    /// Area by breakdown class (Fig 9).
    pub area_breakdown_um2: BTreeMap<&'static str, f64>,
}

/// One point of the energy-vs-frequency sweep (Fig 10).
#[derive(Debug, Clone, Copy)]
pub struct EnergyPoint {
    pub freq_mhz: f64,
    pub voltage: f64,
    pub energy_pj_per_elem: f64,
    pub power_mw: f64,
}

pub struct Synthesizer {
    pub profile: TechProfile,
}

impl Synthesizer {
    pub fn new(profile: TechProfile) -> Synthesizer {
        Synthesizer { profile }
    }

    /// "Synthesize" a unit design at this corner.
    pub fn synthesize(&self, design: &UnitDesign) -> SynthReport {
        let p = &self.profile;
        let mut area_um2 = 0.0;
        let mut energy_pj = 0.0;
        let mut crit_ns: f64 = 0.0;
        let mut breakdown: BTreeMap<&'static str, f64> = BTreeMap::new();

        for inst in &design.instances {
            let i = inst.kind.intrinsic();
            let a = i.area_um2 * inst.count * p.area_scale;
            area_um2 += a;
            *breakdown.entry(inst.kind.breakdown_class()).or_insert(0.0) += a;
            // Energy per processed element at nominal voltage. `activity`
            // counts operations (or word accesses) per element for the
            // whole instance group; storage intrinsics are per *bit*, so
            // scale by the accessed word width — the array size only costs
            // area/leakage, not switching.
            let per_elem = match inst.kind {
                Kind::RegFileBit | Kind::SramBit | Kind::Reg => {
                    i.energy_pj * word_bits(inst.kind) * inst.activity
                }
                _ => i.energy_pj * inst.activity,
            };
            energy_pj += per_elem * p.energy_scale;
            if inst.on_critical_path {
                crit_ns = crit_ns.max(i.delay_ns * p.delay_scale);
            }
        }

        // clock overhead (setup + skew): 15% of the worst stage
        let cycle_ns = crit_ns * 1.15;
        let fmax_mhz = 1000.0 / cycle_ns;
        let leakage_mw = area_um2 * p.leak_uw_per_um2 / 1000.0;

        SynthReport {
            design: design.name.clone(),
            corner: p.name(),
            area_mm2: area_um2 / 1.0e6,
            fmax_mhz,
            energy_pj_per_elem_nominal: energy_pj,
            leakage_mw_nominal: leakage_mw,
            area_breakdown_um2: breakdown,
        }
    }

    /// Power at nominal supply (no DVFS) when streaming at `f_mhz` — the
    /// condition Table I's power footnote measures under.
    pub fn power_mw_nominal(&self, rep: &SynthReport, f_mhz: f64) -> f64 {
        rep.energy_pj_per_elem_nominal * f_mhz * 1e-3 + rep.leakage_mw_nominal
    }

    /// Total power when streaming one element per cycle at `f_mhz`
    /// (voltage scaled to the minimum that sustains `f_mhz`).
    pub fn power_mw_at(&self, rep: &SynthReport, f_mhz: f64) -> Option<f64> {
        let v = self.profile.voltage_for_freq(rep.fmax_mhz, f_mhz)?;
        let dyn_mw = rep.energy_pj_per_elem_nominal
            * self.profile.energy_factor(v)
            * f_mhz
            * 1e-3; // pJ * MHz = µW; /1000 -> mW
        let leak_mw = rep.leakage_mw_nominal * self.profile.leakage_factor(v);
        Some(dyn_mw + leak_mw)
    }

    /// Energy per element at `f_mhz`: dynamic at the scaled voltage plus
    /// leakage amortized over the cycle. This produces Fig 10's U-shape:
    /// low f pays leakage per op, high f pays V² overdrive.
    pub fn energy_pj_at(&self, rep: &SynthReport, f_mhz: f64) -> Option<f64> {
        let v = self.profile.voltage_for_freq(rep.fmax_mhz, f_mhz)?;
        let dyn_pj =
            rep.energy_pj_per_elem_nominal * self.profile.energy_factor(v);
        // 1 mW = 1e9 pJ/s; at f_mhz * 1e6 elements/s the leakage charge
        // per element is leak_mw * 1e9 / (f_mhz * 1e6) = leak_mw * 1e3 / f_mhz.
        let leak_pj = rep.leakage_mw_nominal * self.profile.leakage_factor(v)
            * 1e3
            / f_mhz;
        Some(dyn_pj + leak_pj)
    }

    /// Sweep energy/op across the frequency range (Fig 10) and find the
    /// optimum-energy frequency.
    pub fn energy_sweep(
        &self,
        rep: &SynthReport,
        points: usize,
    ) -> Vec<EnergyPoint> {
        let fmax_v = self
            .profile
            .freq_at_voltage(rep.fmax_mhz, self.profile.vmax);
        let f_lo = rep.fmax_mhz * 0.05;
        (0..points)
            .filter_map(|i| {
                let f = f_lo + (fmax_v - f_lo) * i as f64 / (points - 1) as f64;
                let v = self.profile.voltage_for_freq(rep.fmax_mhz, f)?;
                Some(EnergyPoint {
                    freq_mhz: f,
                    voltage: v,
                    energy_pj_per_elem: self.energy_pj_at(rep, f)?,
                    power_mw: self.power_mw_at(rep, f)?,
                })
            })
            .collect()
    }

    /// The optimum-energy operating point (Fig 10's marked minima).
    pub fn optimum_energy(&self, rep: &SynthReport) -> EnergyPoint {
        self.energy_sweep(rep, 200)
            .into_iter()
            .min_by(|a, b| {
                a.energy_pj_per_elem
                    .partial_cmp(&b.energy_pj_per_elem)
                    .unwrap()
            })
            .expect("non-empty sweep")
    }
}

/// Storage word width per access for energy accounting.
fn word_bits(kind: Kind) -> f64 {
    match kind {
        Kind::RegFileBit => 16.0,
        Kind::SramBit => 16.0,
        _ => 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::designs::{consmax_unit, paper_designs, softermax_unit, softmax_unit, Precision};
    use crate::hw::tech::{EdaFlow, TechNode, TechProfile};

    fn synth16() -> Synthesizer {
        Synthesizer::new(TechProfile::new(TechNode::Fin16, EdaFlow::Proprietary))
    }

    #[test]
    fn consmax_wins_area_and_power_16nm() {
        let s = synth16();
        let reports: Vec<SynthReport> =
            paper_designs(256).iter().map(|d| s.synthesize(d)).collect();
        let (c, soft, sm) = (&reports[0], &reports[1], &reports[2]);
        assert!(c.area_mm2 < soft.area_mm2);
        assert!(soft.area_mm2 < sm.area_mm2);
        let pc = s.power_mw_at(c, 500.0).unwrap();
        let ps = s.power_mw_at(soft, 500.0).unwrap();
        assert!(pc < ps);
    }

    #[test]
    fn table1_16nm_magnitudes() {
        // shape check against the paper's 16nm column: ConSmax ~0.0008 mm²
        // (within ~2x), Softermax/ConSmax area ratio in [1.8, 5],
        // Softmax/ConSmax in [6, 30].
        let s = synth16();
        let c = s.synthesize(&consmax_unit(Precision::Int8));
        let soft = s.synthesize(&softermax_unit(256));
        let sm = s.synthesize(&softmax_unit(256));
        assert!(c.area_mm2 > 0.0003 && c.area_mm2 < 0.0020, "{}", c.area_mm2);
        let r1 = soft.area_mm2 / c.area_mm2;
        let r2 = sm.area_mm2 / c.area_mm2;
        assert!((1.8..5.0).contains(&r1), "softermax/consmax area {r1}");
        assert!((6.0..30.0).contains(&r2), "softmax/consmax area {r2}");
    }

    #[test]
    fn fmax_ordering_matches_paper() {
        // paper: ConSmax 1250 > Softermax 1111 > Softmax 909 (16nm)
        let s = synth16();
        let f = |d: &UnitDesign| s.synthesize(d).fmax_mhz;
        let fc = f(&consmax_unit(Precision::Int8));
        let fs = f(&softermax_unit(256));
        let fm = f(&softmax_unit(256));
        assert!(fc > fs && fs > fm, "fc={fc} fs={fs} fm={fm}");
        assert!(fc > 900.0 && fc < 2500.0, "{fc}");
    }

    #[test]
    fn sky130_slower_and_bigger() {
        let s16 = synth16();
        let s130 = Synthesizer::new(TechProfile::new(
            TechNode::Sky130,
            EdaFlow::Proprietary,
        ));
        let d = consmax_unit(Precision::Int8);
        let r16 = s16.synthesize(&d);
        let r130 = s130.synthesize(&d);
        assert!(r130.area_mm2 > 5.0 * r16.area_mm2);
        assert!(r130.fmax_mhz < r16.fmax_mhz / 1.5);
    }

    #[test]
    fn energy_curve_is_u_shaped() {
        let s = synth16();
        let rep = s.synthesize(&consmax_unit(Precision::Int8));
        let sweep = s.energy_sweep(&rep, 50);
        assert!(sweep.len() > 40);
        let e_lo = sweep.first().unwrap().energy_pj_per_elem;
        let e_hi = sweep.last().unwrap().energy_pj_per_elem;
        let e_min = s.optimum_energy(&rep).energy_pj_per_elem;
        assert!(e_min < e_lo, "leakage should dominate at low f");
        assert!(e_min < e_hi, "overdrive V² should dominate at high f");
    }

    #[test]
    fn optimum_inside_frequency_range() {
        let s = synth16();
        for d in paper_designs(256) {
            let rep = s.synthesize(&d);
            let opt = s.optimum_energy(&rep);
            assert!(opt.freq_mhz > 0.0);
            assert!(
                opt.freq_mhz
                    <= s.profile.freq_at_voltage(rep.fmax_mhz, s.profile.vmax)
                        + 1.0
            );
        }
    }

    #[test]
    fn power_beyond_envelope_is_none() {
        let s = synth16();
        let rep = s.synthesize(&consmax_unit(Precision::Int8));
        assert!(s.power_mw_at(&rep, rep.fmax_mhz * 3.0).is_none());
    }

    #[test]
    fn breakdown_sums_to_total() {
        let s = synth16();
        for d in paper_designs(256) {
            let rep = s.synthesize(&d);
            let sum: f64 = rep.area_breakdown_um2.values().sum();
            assert!((sum / 1e6 - rep.area_mm2).abs() < 1e-9);
        }
    }

    #[test]
    fn softmax_breakdown_dominated_by_storage_and_fp32(){
        let s = synth16();
        let rep = s.synthesize(&softmax_unit(256));
        let storage = rep.area_breakdown_um2["storage"];
        let total = rep.area_mm2 * 1e6;
        assert!(storage / total > 0.25, "storage frac {}", storage / total);
    }
}
