//! Paper-table generators: Table I, Fig 9 (area breakdown + Fmax), Fig 10
//! (energy vs frequency). Each returns structured rows; the bench targets
//! and `examples/hw_report.rs` print them next to the paper's numbers.

use super::designs::{paper_designs, UnitDesign};
use super::synth::{EnergyPoint, SynthReport, Synthesizer};
use super::tech::{EdaFlow, TechNode, TechProfile};

/// Paper Table I reference values (proprietary EDA section).
/// (design, node) -> (fmax_mhz, area_mm2, power_mw, opt_energy_pj)
pub fn paper_table1_reference() -> Vec<(&'static str, &'static str, [f64; 4])> {
    vec![
        ("ConSmax", "16nm", [1250.0, 0.0008, 0.2, 0.2]),
        ("Softermax", "16nm", [1111.0, 0.0022, 0.67, 0.7]),
        ("Softmax", "16nm", [909.0, 0.011, 1.5, 1.5]),
        ("ConSmax", "130nm", [666.67, 0.007, 2.69, 4.0]),
        ("Softermax", "130nm", [333.33, 0.029, 8.5, 25.5]),
        ("Softmax", "130nm", [285.71, 0.18, 51.0, 178.5]),
    ]
}

/// One reproduced Table I row.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub design: String,
    pub corner: String,
    pub fmax_mhz: f64,
    pub area_mm2: f64,
    /// Power at the paper's test frequency (500 MHz @16nm, 80 MHz @130nm).
    pub power_mw: f64,
    pub opt_energy_pj: f64,
    pub opt_energy_freq_mhz: f64,
}

/// The frequency Table I's power footnote uses per node.
pub fn power_test_freq(node: TechNode) -> f64 {
    match node {
        TechNode::Fin16 => 500.0,
        TechNode::Sky130 => 80.0,
    }
}

/// Regenerate Table I for one EDA flow (both nodes, all three designs).
pub fn table1(flow: EdaFlow, seq: usize) -> Vec<Table1Row> {
    let mut rows = Vec::new();
    for node in [TechNode::Fin16, TechNode::Sky130] {
        let synth = Synthesizer::new(TechProfile::new(node, flow));
        let f_test = power_test_freq(node);
        for d in paper_designs(seq) {
            let rep = synth.synthesize(&d);
            let opt = synth.optimum_energy(&rep);
            let power = synth.power_mw_nominal(&rep, f_test.min(rep.fmax_mhz));
            rows.push(Table1Row {
                design: d.name.clone(),
                corner: synth.profile.name(),
                fmax_mhz: rep.fmax_mhz,
                area_mm2: rep.area_mm2,
                power_mw: power,
                opt_energy_pj: opt.energy_pj_per_elem,
                opt_energy_freq_mhz: opt.freq_mhz,
            });
        }
    }
    rows
}

/// Headline savings ratios (the abstract's claims).
#[derive(Debug, Clone)]
pub struct Savings {
    pub corner: String,
    pub vs: String,
    pub power_ratio: f64,
    pub area_ratio: f64,
}

pub fn savings(rows: &[Table1Row]) -> Vec<Savings> {
    let mut out = Vec::new();
    for corner in rows.iter().map(|r| r.corner.clone()).collect::<std::collections::BTreeSet<_>>() {
        let get = |name: &str| {
            rows.iter()
                .find(|r| r.corner == corner && r.design == name)
                .cloned()
        };
        if let (Some(c), Some(soft), Some(sm)) =
            (get("ConSmax"), get("Softermax"), get("Softmax"))
        {
            out.push(Savings {
                corner: corner.clone(),
                vs: "Softermax".into(),
                power_ratio: soft.power_mw / c.power_mw,
                area_ratio: soft.area_mm2 / c.area_mm2,
            });
            out.push(Savings {
                corner: corner.clone(),
                vs: "Softmax".into(),
                power_ratio: sm.power_mw / c.power_mw,
                area_ratio: sm.area_mm2 / c.area_mm2,
            });
        }
    }
    out
}

/// Fig 9: per-design area breakdown (µm² by component class) + Fmax, for
/// one node under both EDA flows.
#[derive(Debug, Clone)]
pub struct Fig9Entry {
    pub design: String,
    pub flow: String,
    pub fmax_mhz: f64,
    pub breakdown_um2: Vec<(&'static str, f64)>,
}

pub fn fig9(node: TechNode, seq: usize) -> Vec<Fig9Entry> {
    let mut out = Vec::new();
    for flow in [EdaFlow::Proprietary, EdaFlow::OpenSource] {
        let synth = Synthesizer::new(TechProfile::new(node, flow));
        for d in paper_designs(seq) {
            let rep = synth.synthesize(&d);
            out.push(Fig9Entry {
                design: d.name.clone(),
                flow: match flow {
                    EdaFlow::Proprietary => "proprietary".into(),
                    EdaFlow::OpenSource => "opensource".into(),
                },
                fmax_mhz: rep.fmax_mhz,
                breakdown_um2: rep
                    .area_breakdown_um2
                    .iter()
                    .map(|(k, v)| (*k, *v))
                    .collect(),
            });
        }
    }
    out
}

/// Fig 10: energy-vs-frequency series for each design at a corner.
pub fn fig10(
    node: TechNode,
    flow: EdaFlow,
    seq: usize,
    points: usize,
) -> Vec<(String, Vec<EnergyPoint>, EnergyPoint)> {
    let synth = Synthesizer::new(TechProfile::new(node, flow));
    paper_designs(seq)
        .iter()
        .map(|d| {
            let rep = synth.synthesize(d);
            let sweep = synth.energy_sweep(&rep, points);
            let opt = synth.optimum_energy(&rep);
            (d.name.clone(), sweep, opt)
        })
        .collect()
}

/// Sequence-length ablation: area of each design as the context grows
/// (DESIGN.md's long-context claim; not a paper figure but the paper's
/// §III-A argument quantified).
pub fn area_vs_seq(node: TechNode, seqs: &[usize]) -> Vec<(String, Vec<(usize, f64)>)> {
    let synth = Synthesizer::new(TechProfile::new(node, EdaFlow::Proprietary));
    let names = ["ConSmax", "Softermax", "Softmax"];
    let mut series: Vec<(String, Vec<(usize, f64)>)> =
        names.iter().map(|n| (n.to_string(), Vec::new())).collect();
    for &seq in seqs {
        for (i, d) in paper_designs(seq).iter().enumerate() {
            let rep = synth.synthesize(d);
            series[i].1.push((seq, rep.area_mm2));
        }
    }
    series
}

/// Convenience: synthesize one design everywhere (tests + examples).
pub fn synthesize_at(
    design: &UnitDesign,
    node: TechNode,
    flow: EdaFlow,
) -> SynthReport {
    Synthesizer::new(TechProfile::new(node, flow)).synthesize(design)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_six_rows_per_flow() {
        let rows = table1(EdaFlow::Proprietary, 256);
        assert_eq!(rows.len(), 6);
        assert!(rows.iter().any(|r| r.corner == "16nm/proprietary"));
        assert!(rows.iter().any(|r| r.corner == "130nm/proprietary"));
    }

    #[test]
    fn consmax_wins_every_corner_and_metric() {
        for flow in [EdaFlow::Proprietary, EdaFlow::OpenSource] {
            let rows = table1(flow, 256);
            for corner in ["16nm", "130nm"] {
                let of = |n: &str| {
                    rows.iter()
                        .find(|r| r.design == n && r.corner.starts_with(corner))
                        .unwrap()
                        .clone()
                };
                let c = of("ConSmax");
                for other in ["Softermax", "Softmax"] {
                    let o = of(other);
                    assert!(c.area_mm2 < o.area_mm2, "{corner} {other} area");
                    assert!(c.power_mw < o.power_mw, "{corner} {other} power");
                    assert!(c.fmax_mhz > o.fmax_mhz, "{corner} {other} fmax");
                    assert!(
                        c.opt_energy_pj < o.opt_energy_pj,
                        "{corner} {other} energy"
                    );
                }
            }
        }
    }

    #[test]
    fn savings_ratios_in_paper_ballpark() {
        // paper 16nm: 3.35x power, 2.75x area vs Softermax; 7.5x/13.75x vs
        // Softmax. Accept the right order of magnitude (cost model, not DC).
        let rows = table1(EdaFlow::Proprietary, 256);
        let s = savings(&rows);
        let soft16 = s
            .iter()
            .find(|x| x.corner.starts_with("16nm") && x.vs == "Softermax")
            .unwrap();
        assert!(
            (1.5..8.0).contains(&soft16.power_ratio),
            "power ratio {}",
            soft16.power_ratio
        );
        assert!(
            (1.8..6.0).contains(&soft16.area_ratio),
            "area ratio {}",
            soft16.area_ratio
        );
        let sm16 = s
            .iter()
            .find(|x| x.corner.starts_with("16nm") && x.vs == "Softmax")
            .unwrap();
        assert!(
            (4.0..40.0).contains(&sm16.power_ratio),
            "power ratio {}",
            sm16.power_ratio
        );
        assert!(
            (6.0..30.0).contains(&sm16.area_ratio),
            "area ratio {}",
            sm16.area_ratio
        );
    }

    #[test]
    fn fig9_covers_both_flows_and_designs() {
        let f = fig9(TechNode::Fin16, 256);
        assert_eq!(f.len(), 6);
        // softmax has a divider slice, consmax doesn't
        let cs = f.iter().find(|e| e.design == "ConSmax").unwrap();
        assert!(cs.breakdown_um2.iter().all(|(k, _)| *k != "divider"));
        let sm = f.iter().find(|e| e.design == "Softmax").unwrap();
        assert!(sm.breakdown_um2.iter().any(|(k, v)| *k == "divider" && *v > 0.0));
    }

    #[test]
    fn fig10_optima_roughly_at_paper_frequencies() {
        // paper 16nm: optima at 666 MHz (ConSmax/Softermax), 714 (Softmax)
        // — i.e. mid-band, not at either end. Check each optimum is inside
        // (20%, 95%) of its achievable range.
        let series = fig10(TechNode::Fin16, EdaFlow::Proprietary, 256, 100);
        for (name, sweep, opt) in series {
            let f_hi = sweep.last().unwrap().freq_mhz;
            assert!(
                opt.freq_mhz > 0.2 * f_hi && opt.freq_mhz < 0.98 * f_hi,
                "{name}: optimum {:.0} MHz of {:.0}",
                opt.freq_mhz,
                f_hi
            );
        }
    }

    #[test]
    fn area_vs_seq_consmax_flat_baselines_grow() {
        let series = area_vs_seq(TechNode::Fin16, &[256, 1024, 4096]);
        let consmax = &series[0].1;
        assert!((consmax[0].1 - consmax[2].1).abs() < 1e-12);
        let softermax = &series[1].1;
        assert!(softermax[2].1 > 3.0 * softermax[0].1);
        let softmax = &series[2].1;
        assert!(softmax[2].1 > 3.0 * softmax[0].1);
    }

    #[test]
    fn paper_reference_is_consistent() {
        let refs = paper_table1_reference();
        assert_eq!(refs.len(), 6);
        // paper's own abstract ratios: 3.35x power, 2.75x area (16nm)
        let get = |d: &str, n: &str| {
            refs.iter().find(|(dd, nn, _)| *dd == d && *nn == n).unwrap().2
        };
        let c = get("ConSmax", "16nm");
        let s = get("Softermax", "16nm");
        assert!((s[2] / c[2] - 3.35).abs() < 0.01);
        assert!((s[1] / c[1] - 2.75).abs() < 0.01);
    }
}
