//! Netlists for the three normalizer units, built from the component
//! library. Structure follows the paper:
//!
//! * **ConSmax** (Fig 4a): bitwidth-split LUT pair + FP16 multiplier chain
//!   + FP→INT converter. *No* max search, *no* accumulator, *no* divider,
//!   *no* score buffer — the score stream normalizes element-by-element.
//! * **Softermax** (Stevens et al.): running max + base-2 LUT exponential
//!   + running-sum accumulator + reciprocal-and-rescale pass, which forces
//!   a sequence-length score buffer (double-buffered).
//! * **Softmax** (DesignWare-style): exact two-pass softmax — max tree,
//!   FP32 exp (LUT + Taylor refinement), FP32 accumulation, FP32 division,
//!   with a full-precision double buffer.
//!
//! Buffer sizes scale with the token sequence length, which is exactly the
//! long-context pain the paper describes (§III-A); ConSmax's netlist is
//! the only one independent of sequence length.

use super::component::{Instance, Kind};

/// A synthesizable unit: name + instance groups.
#[derive(Debug, Clone)]
pub struct UnitDesign {
    pub name: String,
    pub instances: Vec<Instance>,
    /// Elements processed per clock in steady state (pipeline throughput).
    pub elems_per_cycle: f64,
}

impl UnitDesign {
    pub fn total_area_instances(&self) -> f64 {
        self.instances.iter().map(|i| i.count).sum()
    }
}

/// Precision of the score input stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Int8,
    /// INT16 via the reduction unit: two bitwidth-split units + an extra
    /// merge multiplier (paper §IV-A2).
    Int16,
}

/// The ConSmax unit of Fig 4(a).
///
/// Datapath per score element: two 16-entry×16b LUT reads (MSB/LSB nibble)
/// → FP16 multiply (merge, Eq. 4) → FP16 multiply (×C) → FP→INT convert.
/// Fully pipelined, one element per cycle, no sequence-length state.
pub fn consmax_unit(precision: Precision) -> UnitDesign {
    let units = match precision {
        Precision::Int8 => 1.0,
        Precision::Int16 => 2.0,
    };
    let mut instances = vec![
        // 2 LUTs × 16 entries × 16 bits, regfile-class storage
        Instance::new(Kind::RegFileBit, units * 2.0 * 16.0 * 16.0, 2.0).critical(),
        // LUT-merge multiplier + C multiplier
        Instance::new(Kind::FpMul16, units * 2.0, 2.0).critical(),
        // output converter
        Instance::new(Kind::FpToInt, units, 1.0),
        // I/O + pipeline registers: in(8b) + two fp16 stages + out(16b)
        Instance::new(Kind::Reg, units * (8.0 + 16.0 + 16.0 + 16.0), 4.0),
        Instance::new(Kind::Control, 1.0, 1.0),
    ];
    if precision == Precision::Int16 {
        // reduction-unit merge multiplier chaining the two 8-bit slices
        instances.push(Instance::new(Kind::FpMul16, 1.0, 1.0).critical());
    }
    UnitDesign {
        name: match precision {
            Precision::Int8 => "ConSmax".into(),
            Precision::Int16 => "ConSmax-16b".into(),
        },
        instances,
        elems_per_cycle: 1.0,
    }
}

/// Softermax unit (base-2 partial softmax) for a score vector of `seq`.
///
/// Pass 1 streams scores through a running max + base-2 exponential +
/// running sum, buffering 2^(s−m) per element; pass 2 rescales each
/// buffered value by the reciprocal of the final sum (and the max
/// correction). The buffer is double-banked so passes overlap across
/// tokens. Effective throughput ~1 element/cycle but every element is
/// touched twice.
pub fn softermax_unit(seq: usize) -> UnitDesign {
    let seq = seq as f64;
    UnitDesign {
        name: "Softermax".into(),
        instances: vec![
            // running max over dequantized scores
            Instance::new(Kind::CmpFp16, 1.0, 1.0),
            // subtract (s - max) on the accumulate path
            Instance::new(Kind::FpAdd16, 1.0, 1.0).critical(),
            // base-2 exponential: 16-entry LUT + linear-interp mult-add
            Instance::new(Kind::RegFileBit, 16.0 * 16.0, 1.0),
            Instance::new(Kind::FpMul16, 1.0, 1.0).critical(),
            Instance::new(Kind::FpAdd16, 1.0, 1.0),
            // running-sum accumulator
            Instance::new(Kind::FpAdd16, 1.0, 1.0).critical(),
            // reciprocal: seed LUT + 1 Newton step (2 mult + 1 add),
            // amortized once per vector but synthesized in full
            Instance::new(Kind::RegFileBit, 32.0 * 16.0, 1.0 / seq),
            Instance::new(Kind::FpMul16, 2.0, 2.0 / seq),
            Instance::new(Kind::FpAdd16, 1.0, 1.0 / seq),
            // rescale multiply on pass 2
            Instance::new(Kind::FpMul16, 1.0, 1.0),
            // double-buffered score storage: 2 × seq × 16 bits
            Instance::new(Kind::SramBit, 2.0 * seq * 16.0, 2.0),
            // pipeline/IO regs
            Instance::new(Kind::Reg, 8.0 + 16.0 * 3.0, 4.0),
            Instance::new(Kind::Control, 2.0, 1.0),
        ],
        elems_per_cycle: 1.0,
    }
}

/// DesignWare-style exact Softmax unit for a score vector of `seq`.
///
/// Two passes in FP32: (1) max search, (2) exp(s−max) via LUT + 2-term
/// Taylor refinement, accumulate; then a division per element. The full
/// vector is buffered at 32 bits, double-banked.
pub fn softmax_unit(seq: usize) -> UnitDesign {
    let seq = seq as f64;
    UnitDesign {
        name: "Softmax".into(),
        instances: vec![
            // pass-1 max: FP32-class comparator (8 int8 lanes equiv)
            Instance::new(Kind::CmpFp16, 2.0, 1.0),
            // exp datapath: range reduction add + LUT + 2 Taylor terms
            // (2 mult-add pairs) + reconstruction multiply, FP32
            Instance::new(Kind::FpAdd32, 1.0, 1.0).critical(),
            Instance::new(Kind::RegFileBit, 64.0 * 32.0, 1.0),
            Instance::new(Kind::FpMul32, 3.0, 3.0).critical(),
            Instance::new(Kind::FpAdd32, 2.0, 2.0),
            // accumulator
            Instance::new(Kind::FpAdd32, 1.0, 1.0).critical(),
            // divider (normalization, per element)
            Instance::new(Kind::FpDiv32, 1.0, 1.0).critical(),
            // double-buffered FP32 score storage
            Instance::new(Kind::SramBit, 2.0 * seq * 32.0, 2.0),
            // wider pipeline/IO registers
            Instance::new(Kind::Reg, 8.0 + 32.0 * 4.0, 4.0),
            Instance::new(Kind::Control, 3.0, 1.0),
        ],
        elems_per_cycle: 1.0,
    }
}

/// All three designs at the paper's workload (seq tokens, INT8 scores).
pub fn paper_designs(seq: usize) -> Vec<UnitDesign> {
    vec![
        consmax_unit(Precision::Int8),
        softermax_unit(seq),
        softmax_unit(seq),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consmax_has_no_sequence_state() {
        let a = consmax_unit(Precision::Int8);
        // identical netlist regardless of seq (nothing takes seq at all):
        // the type system enforces it — this test documents it.
        assert!(a.instances.iter().all(|i| i.count < 1000.0));
    }

    #[test]
    fn baselines_scale_with_sequence() {
        let s256 = softermax_unit(256);
        let s4k = softermax_unit(4096);
        let bits = |d: &UnitDesign| -> f64 {
            d.instances
                .iter()
                .filter(|i| i.kind == Kind::SramBit)
                .map(|i| i.count)
                .sum()
        };
        assert!(bits(&s4k) > 10.0 * bits(&s256));
        let m256 = softmax_unit(256);
        let m4k = softmax_unit(4096);
        assert!(bits(&m4k) > 10.0 * bits(&m256));
    }

    #[test]
    fn softmax_buffers_twice_the_bits_of_softermax() {
        let bits = |d: &UnitDesign| -> f64 {
            d.instances
                .iter()
                .filter(|i| i.kind == Kind::SramBit)
                .map(|i| i.count)
                .sum()
        };
        assert_eq!(bits(&softmax_unit(256)), 2.0 * bits(&softermax_unit(256)));
    }

    #[test]
    fn consmax_lacks_divider_and_accumulator() {
        let d = consmax_unit(Precision::Int8);
        assert!(d.instances.iter().all(|i| i.kind != Kind::FpDiv32));
        assert!(d.instances.iter().all(|i| i.kind != Kind::FpAdd32));
        assert!(d.instances.iter().all(|i| i.kind != Kind::FpAdd16));
        assert!(d.instances.iter().all(|i| i.kind != Kind::CmpFp16));
    }

    #[test]
    fn int16_uses_two_split_units_plus_merge() {
        let d8 = consmax_unit(Precision::Int8);
        let d16 = consmax_unit(Precision::Int16);
        let muls = |d: &UnitDesign| -> f64 {
            d.instances
                .iter()
                .filter(|i| i.kind == Kind::FpMul16)
                .map(|i| i.count)
                .sum()
        };
        assert_eq!(muls(&d8), 2.0);
        assert_eq!(muls(&d16), 5.0); // 2x2 split + 1 reduction merge
    }

    #[test]
    fn lut_capacity_is_the_bitwidth_split_one() {
        // 2 x 16 entries x 16 bits = 512 bits, NOT 256 x 16 = 4096: the
        // whole point of the nibble split (paper §IV-A1).
        let d = consmax_unit(Precision::Int8);
        let lut_bits: f64 = d
            .instances
            .iter()
            .filter(|i| i.kind == Kind::RegFileBit)
            .map(|i| i.count)
            .sum();
        assert_eq!(lut_bits, 512.0);
    }
}
