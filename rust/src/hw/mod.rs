//! Hardware substrate: the synthesis estimator that stands in for the
//! paper's Synopsys-DC / OpenROAD flows (DESIGN.md §2 documents the
//! substitution). Netlists for the three normalizer units are costed with
//! a calibrated component library under four (node, flow) corners to
//! regenerate Table I, Fig 9 and Fig 10.

pub mod component;
pub mod designs;
pub mod report;
pub mod rtl;
pub mod synth;
pub mod tech;

pub use designs::{consmax_unit, paper_designs, softermax_unit, softmax_unit, Precision, UnitDesign};
pub use report::{fig10, fig9, savings, table1, Table1Row};
pub use synth::{SynthReport, Synthesizer};
pub use tech::{EdaFlow, TechNode, TechProfile};
