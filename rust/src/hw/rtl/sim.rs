//! Cycle- and bit-accurate structural simulator of the generated
//! `consmax_unit.v`.
//!
//! Every clocked element of the Verilog has a corresponding field here
//! (stage-1 ROM-output registers, stage-2 merge-product register,
//! stage-3 output register, and the valid chain), and the combinational
//! fp16 multiplies use [`crate::util::fp16::F16::mul`] — the same
//! round-to-nearest-even semantics the behavioral `fp16_mul.v`
//! implements. Tests pin the simulator against [`BitSplitLut`] (and thus
//! against the python goldens) over the exhaustive input grid, and check
//! the pipeline timing contract (latency 3, II 1, reset behaviour).

use crate::quant::BitSplitLut;
use crate::util::fp16::F16;

/// Input to one clock cycle.
#[derive(Debug, Clone, Copy)]
pub struct SimInput {
    pub valid: bool,
    pub score: i8,
    pub c_const: F16,
}

impl SimInput {
    pub fn bubble() -> SimInput {
        SimInput { valid: false, score: 0, c_const: F16::ZERO }
    }
}

/// Output of one clock cycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimOutput {
    pub valid: bool,
    pub prob: F16,
}

/// The structural model of `consmax_unit.v`.
#[derive(Debug, Clone)]
pub struct ConsmaxUnitSim {
    rom_msb: [F16; 16],
    rom_lsb: [F16; 16],
    // stage 1 registers
    v1: bool,
    r_msb: F16,
    r_lsb: F16,
    r_c1: F16,
    // stage 2 registers
    v2: bool,
    r_exp: F16,
    r_c2: F16,
    // stage 3 registers
    v3: bool,
    r_out: F16,
    /// Cycles elapsed since reset (for timing assertions).
    pub cycle: u64,
}

impl ConsmaxUnitSim {
    /// Build with the ROM image for `scale` (identical to the Verilog
    /// emitter's tables).
    pub fn new(scale: f32) -> ConsmaxUnitSim {
        let lut = BitSplitLut::new(scale);
        let (msb_bits, lsb_bits) = lut.table_bits();
        let mut rom_msb = [F16::ZERO; 16];
        let mut rom_lsb = [F16::ZERO; 16];
        for i in 0..16 {
            rom_msb[i] = F16::from_bits(msb_bits[i]);
            rom_lsb[i] = F16::from_bits(lsb_bits[i]);
        }
        ConsmaxUnitSim {
            rom_msb,
            rom_lsb,
            v1: false,
            r_msb: F16::ZERO,
            r_lsb: F16::ZERO,
            r_c1: F16::ZERO,
            v2: false,
            r_exp: F16::ZERO,
            r_c2: F16::ZERO,
            v3: false,
            r_out: F16::ZERO,
            cycle: 0,
        }
    }

    /// Asynchronous reset (rst_n low): clears the valid chain.
    pub fn reset(&mut self) {
        self.v1 = false;
        self.v2 = false;
        self.v3 = false;
        self.r_msb = F16::ZERO;
        self.r_lsb = F16::ZERO;
        self.r_c1 = F16::ZERO;
        self.r_exp = F16::ZERO;
        self.r_c2 = F16::ZERO;
        self.r_out = F16::ZERO;
        self.cycle = 0;
    }

    /// One posedge: returns the output *after* the edge (what a checker
    /// sampling on the following negedge would see).
    pub fn clock(&mut self, input: SimInput) -> SimOutput {
        // combinational stage 0: nibble split + ROM read (pre-edge values)
        let (mi, li) = BitSplitLut::split(input.score);
        let msb_val = self.rom_msb[mi];
        let lsb_val = self.rom_lsb[li];
        // combinational stage 2 input: merge multiply from stage-1 regs
        let merge_p = self.r_msb.mul(self.r_lsb);
        // combinational stage 3 input: C multiply from stage-2 regs
        let final_p = self.r_exp.mul(self.r_c2);

        // clock edge: shift the pipeline (reverse order, like the RTL's
        // simultaneous nonblocking assignments)
        self.v3 = self.v2;
        self.r_out = final_p;
        self.v2 = self.v1;
        self.r_exp = merge_p;
        self.r_c2 = self.r_c1;
        self.v1 = input.valid;
        self.r_msb = msb_val;
        self.r_lsb = lsb_val;
        self.r_c1 = input.c_const;
        self.cycle += 1;

        SimOutput { valid: self.v3, prob: self.r_out }
    }

    /// Stream a slice of scores at full rate (II = 1) and collect the
    /// valid outputs. Drains the pipeline with bubbles at the end.
    pub fn run_stream(&mut self, scores: &[i8], c: F16) -> Vec<F16> {
        let mut out = Vec::with_capacity(scores.len());
        for &q in scores {
            let o = self.clock(SimInput { valid: true, score: q, c_const: c });
            if o.valid {
                out.push(o.prob);
            }
        }
        for _ in 0..4 {
            let o = self.clock(SimInput::bubble());
            if o.valid {
                out.push(o.prob);
            }
        }
        out
    }

    /// Pipeline latency in cycles (input edge to output-valid edge).
    pub const LATENCY: u64 = 3;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_exact_vs_software_model_full_grid() {
        // the central check: RTL semantics == BitSplitLut == python golden
        let lut = BitSplitLut::paper();
        let c = F16::from_f32(0.013);
        let mut sim = ConsmaxUnitSim::new(1.0 / 16.0);
        let scores: Vec<i8> = (-128i16..=127).map(|q| q as i8).collect();
        let outs = sim.run_stream(&scores, c);
        assert_eq!(outs.len(), 256);
        for (q, got) in scores.iter().zip(&outs) {
            let want = lut.consmax(*q, c);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "q={q}: sim {:#06x} vs model {:#06x}",
                got.to_bits(),
                want.to_bits()
            );
        }
    }

    #[test]
    fn latency_is_three_cycles() {
        let mut sim = ConsmaxUnitSim::new(1.0 / 16.0);
        let c = F16::from_f32(1.0);
        // first input at cycle 1; output must appear exactly at cycle 3
        let o1 = sim.clock(SimInput { valid: true, score: 0, c_const: c });
        assert!(!o1.valid);
        let o2 = sim.clock(SimInput::bubble());
        assert!(!o2.valid);
        let o3 = sim.clock(SimInput::bubble());
        assert!(o3.valid, "latency should be exactly {}", ConsmaxUnitSim::LATENCY);
        // exp(0)*1.0 = 1.0
        assert_eq!(o3.prob.to_bits(), F16::ONE.to_bits());
        let o4 = sim.clock(SimInput::bubble());
        assert!(!o4.valid, "single input must produce single output");
    }

    #[test]
    fn initiation_interval_is_one() {
        // back-to-back inputs yield back-to-back outputs, no bubbles
        let mut sim = ConsmaxUnitSim::new(1.0 / 16.0);
        let c = F16::from_f32(0.5);
        let mut valid_run = 0;
        for i in 0..20 {
            let o = sim.clock(SimInput { valid: true, score: (i % 5) as i8, c_const: c });
            if o.valid {
                valid_run += 1;
            } else {
                assert!(valid_run == 0, "bubble after outputs started");
            }
        }
        // input sampled at edge N is visible on the return of edge
        // N + LATENCY - 1 (3 edges involved end to end)
        assert_eq!(valid_run, 20 - (ConsmaxUnitSim::LATENCY as usize - 1));
    }

    #[test]
    fn bubbles_propagate() {
        let mut sim = ConsmaxUnitSim::new(1.0 / 16.0);
        let c = F16::from_f32(0.5);
        // pattern: valid, bubble, valid -> outputs follow same pattern
        let mut outs = Vec::new();
        for (v, q) in [(true, 1i8), (false, 0), (true, 2), (false, 0), (false, 0), (false, 0)] {
            outs.push(sim.clock(SimInput { valid: v, score: q, c_const: c }).valid);
        }
        // inputs at edges 1 and 3 emerge on the returns of edges 3 and 5
        assert_eq!(outs, vec![false, false, true, false, true, false]);
    }

    #[test]
    fn reset_clears_pipeline() {
        let mut sim = ConsmaxUnitSim::new(1.0 / 16.0);
        let c = F16::from_f32(0.5);
        sim.clock(SimInput { valid: true, score: 3, c_const: c });
        sim.clock(SimInput { valid: true, score: 4, c_const: c });
        sim.reset();
        assert_eq!(sim.cycle, 0);
        for _ in 0..3 {
            assert!(!sim.clock(SimInput::bubble()).valid);
        }
    }

    #[test]
    fn per_element_c_travels_with_data() {
        // different C per element (mixed-head streams): each output must
        // use the C that entered with its score
        let lut = BitSplitLut::paper();
        let mut sim = ConsmaxUnitSim::new(1.0 / 16.0);
        let cs = [0.013f32, 0.5, 0.002];
        let qs = [10i8, 10, 10];
        let mut outs = Vec::new();
        for (q, c) in qs.iter().zip(&cs) {
            let o = sim.clock(SimInput {
                valid: true,
                score: *q,
                c_const: F16::from_f32(*c),
            });
            if o.valid {
                outs.push(o.prob);
            }
        }
        for _ in 0..3 {
            let o = sim.clock(SimInput::bubble());
            if o.valid {
                outs.push(o.prob);
            }
        }
        assert_eq!(outs.len(), 3);
        for ((q, c), got) in qs.iter().zip(&cs).zip(&outs) {
            let want = lut.consmax(*q, F16::from_f32(*c));
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn matches_other_scales() {
        for scale in [1.0f32 / 8.0, 1.0 / 32.0, 1.0 / 64.0] {
            let lut = BitSplitLut::new(scale);
            let c = F16::from_f32(0.1);
            let mut sim = ConsmaxUnitSim::new(scale);
            let scores: Vec<i8> = (-128i16..=127).step_by(3).map(|q| q as i8).collect();
            let outs = sim.run_stream(&scores, c);
            for (q, got) in scores.iter().zip(&outs) {
                assert_eq!(got.to_bits(), lut.consmax(*q, c).to_bits(), "scale {scale} q {q}");
            }
        }
    }

    #[test]
    fn throughput_one_elem_per_cycle_over_long_stream() {
        let mut sim = ConsmaxUnitSim::new(1.0 / 16.0);
        let scores: Vec<i8> = (0..10_000).map(|i| (i % 251) as u8 as i8).collect();
        let outs = sim.run_stream(&scores, F16::from_f32(0.01));
        assert_eq!(outs.len(), scores.len());
        // cycles = inputs + drain
        assert_eq!(sim.cycle, scores.len() as u64 + 4);
    }
}
