//! Verilog RTL for the ConSmax hardware unit (paper §IV / §V-A: "We have
//! developed a ConSmax prototype using Verilog RTL").
//!
//! Two halves:
//!
//! * [`verilog`] — emits synthesizable Verilog for the bitwidth-split
//!   ConSmax unit of Fig 4(a): nibble-split, two 16-entry fp16 ROMs
//!   (contents generated from [`crate::quant::BitSplitLut`], so the ROM
//!   image is bit-identical to the software model and the python
//!   goldens), an fp16 multiplier chain, and the valid-chain pipeline
//!   control. Plus a self-checking testbench that sweeps all 256 input
//!   codes.
//! * [`sim`] — a cycle- and bit-accurate structural simulator of that
//!   exact design (same registers, same ROMs, same rounding), used to
//!   verify the RTL's semantics in-repo: every clocked element of the
//!   Verilog has a field in the simulator, and the tests pin the
//!   simulator to the software LUT model over the exhaustive grid.
//!
//! The generated RTL has no vendor dependencies: the fp16 multiplier is
//! a behavioral IEEE-754 half multiplier (RNE) that synthesis maps to
//! DesignWare/generic arithmetic cells.

pub mod sim;
pub mod verilog;

pub use sim::{ConsmaxUnitSim, SimInput};
pub use verilog::{emit_consmax_unit, emit_fp16_mul, emit_testbench, RtlBundle};
