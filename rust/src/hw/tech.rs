//! Technology nodes and EDA-flow profiles.
//!
//! The paper synthesizes three designs under (16nm FinFET, Synopsys DC)
//! and (SkyWater 130nm, OpenROAD). Neither PDK nor toolchain is available
//! here, so this module captures both as *scaling profiles* applied to a
//! component-level cost model calibrated at the 16nm-proprietary corner
//! (see `component.rs`). The profile factors are drawn from public
//! node-to-node scaling data (gate density, FO4 delay, CV² energy) and
//! from the flow-efficiency gap the paper itself reports between DC and
//! OpenROAD. Absolute numbers are estimates; the *ratios between designs*
//! — Table I's actual claim — come from the datapath structure, not from
//! these constants.

/// Process node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TechNode {
    /// 16nm FinFET, 0.8 V nominal (the paper's proprietary corner).
    Fin16,
    /// SkyWater 130nm CMOS, 1.8 V nominal core (paper uses 0.8 V for the
    /// 130nm power tests; we keep their operating point).
    Sky130,
}

/// Synthesis flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdaFlow {
    /// Synopsys Design Compiler class results.
    Proprietary,
    /// OpenROAD / open-source flow: the paper's own data shows lower
    /// achieved Fmax and looser placement for the same RTL.
    OpenSource,
}

/// Scaling profile relative to the (Fin16, Proprietary) calibration corner.
#[derive(Debug, Clone, Copy)]
pub struct TechProfile {
    pub node: TechNode,
    pub flow: EdaFlow,
    /// Area multiplier per component.
    pub area_scale: f64,
    /// Combinational delay multiplier (FO4 ratio).
    pub delay_scale: f64,
    /// Switching energy multiplier (C·V² ratio).
    pub energy_scale: f64,
    /// Leakage power density, µW per µm² at nominal voltage.
    pub leak_uw_per_um2: f64,
    /// Nominal supply (V).
    pub vnom: f64,
    /// Threshold-ish voltage floor for the linear f(V) model (V).
    pub vt: f64,
    /// Max overdrive supply (V).
    pub vmax: f64,
}

impl TechProfile {
    pub fn new(node: TechNode, flow: EdaFlow) -> TechProfile {
        // Node scaling vs 16nm FinFET.
        // area: 130nm has ~12x the per-gate area of a 16nm FinFET library
        //   cell once FinFET density and routing overhead are folded in
        //   (consistent with the paper's measured 9-16x area ratios).
        // delay: FO4(130nm)/FO4(16nm) ~ 2.4 at matched corners.
        // energy: C and V both larger; CV^2 per gate ~ 25x.
        // Sky130 runs at its 1.8 V nominal core supply (the paper's 130nm
        // power column is consistent with a nominal-voltage test, not a
        // DVFS point): CV² vs the 16nm/0.8V corner is ~ 8x capacitance x
        // 5x V² ≈ 40x, plus wire-dominated old-node caps → ~90x.
        let (area_scale, delay_scale, energy_scale, leak, vnom, vt, vmax) =
            match node {
                TechNode::Fin16 => (1.0, 1.0, 1.0, 0.12, 0.80, 0.38, 0.95),
                TechNode::Sky130 => (12.0, 2.4, 90.0, 0.004, 1.80, 0.55, 1.90),
            };
        // Flow derating: the paper's Fig 9(c)/10(c) comparison shows the
        // open flow trails DC on Fmax and area for identical RTL on the
        // bigger designs (~15-40%); energy follows area.
        let (fa, fd, fe) = match flow {
            EdaFlow::Proprietary => (1.0, 1.0, 1.0),
            EdaFlow::OpenSource => (1.30, 1.25, 1.20),
        };
        TechProfile {
            node,
            flow,
            area_scale: area_scale * fa,
            delay_scale: delay_scale * fd,
            energy_scale: energy_scale * fe,
            leak_uw_per_um2: leak,
            vnom,
            vt,
            vmax,
        }
    }

    pub fn name(&self) -> String {
        let n = match self.node {
            TechNode::Fin16 => "16nm",
            TechNode::Sky130 => "130nm",
        };
        let f = match self.flow {
            EdaFlow::Proprietary => "proprietary",
            EdaFlow::OpenSource => "opensource",
        };
        format!("{n}/{f}")
    }

    /// Frequency achievable at supply `v`, given the critical path at
    /// nominal voltage. Linear alpha-power-ish model:
    /// f(v) = fnom * (v - vt) / (vnom - vt).
    pub fn freq_at_voltage(&self, fnom_mhz: f64, v: f64) -> f64 {
        if v <= self.vt {
            return 0.0;
        }
        fnom_mhz * (v - self.vt) / (self.vnom - self.vt)
    }

    /// Minimum supply voltage to run at `f_mhz` (inverse of the above),
    /// clamped to [vt + margin, vmax]. Returns None when f > f(vmax).
    pub fn voltage_for_freq(&self, fnom_mhz: f64, f_mhz: f64) -> Option<f64> {
        let v = self.vt + (f_mhz / fnom_mhz) * (self.vnom - self.vt);
        if v > self.vmax + 1e-12 {
            None
        } else {
            Some(v.max(self.vt + 0.05))
        }
    }

    /// Dynamic-energy multiplier at supply `v` relative to nominal: (v/vnom)^2.
    pub fn energy_factor(&self, v: f64) -> f64 {
        (v / self.vnom) * (v / self.vnom)
    }

    /// Leakage-power multiplier at supply `v` (roughly linear-exponential;
    /// a gentle super-linear term captures DIBL).
    pub fn leakage_factor(&self, v: f64) -> f64 {
        let r = v / self.vnom;
        r * r.sqrt()
    }

    /// All four corners the paper evaluates.
    pub fn all_corners() -> Vec<TechProfile> {
        vec![
            TechProfile::new(TechNode::Fin16, EdaFlow::Proprietary),
            TechProfile::new(TechNode::Sky130, EdaFlow::Proprietary),
            TechProfile::new(TechNode::Fin16, EdaFlow::OpenSource),
            TechProfile::new(TechNode::Sky130, EdaFlow::OpenSource),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_corner_is_identity() {
        let p = TechProfile::new(TechNode::Fin16, EdaFlow::Proprietary);
        assert_eq!(p.area_scale, 1.0);
        assert_eq!(p.delay_scale, 1.0);
        assert_eq!(p.energy_scale, 1.0);
    }

    #[test]
    fn sky130_is_bigger_slower_hungrier() {
        let p16 = TechProfile::new(TechNode::Fin16, EdaFlow::Proprietary);
        let p130 = TechProfile::new(TechNode::Sky130, EdaFlow::Proprietary);
        assert!(p130.area_scale > 5.0 * p16.area_scale);
        assert!(p130.delay_scale > p16.delay_scale);
        assert!(p130.energy_scale > 10.0 * p16.energy_scale);
    }

    #[test]
    fn open_flow_derates_every_axis() {
        let prop = TechProfile::new(TechNode::Fin16, EdaFlow::Proprietary);
        let open = TechProfile::new(TechNode::Fin16, EdaFlow::OpenSource);
        assert!(open.area_scale > prop.area_scale);
        assert!(open.delay_scale > prop.delay_scale);
        assert!(open.energy_scale > prop.energy_scale);
    }

    #[test]
    fn voltage_frequency_roundtrip() {
        let p = TechProfile::new(TechNode::Fin16, EdaFlow::Proprietary);
        let fnom = 1000.0;
        let v = p.voltage_for_freq(fnom, 600.0).unwrap();
        let f = p.freq_at_voltage(fnom, v);
        assert!((f - 600.0).abs() < 1e-9, "{f}");
    }

    #[test]
    fn overclock_needs_overdrive() {
        let p = TechProfile::new(TechNode::Fin16, EdaFlow::Proprietary);
        // nominal fmax at vnom; a little past it needs v > vnom
        let v = p.voltage_for_freq(1000.0, 1100.0).unwrap();
        assert!(v > p.vnom);
        // far past vmax is unreachable
        assert!(p.voltage_for_freq(1000.0, 2500.0).is_none());
    }

    #[test]
    fn below_vt_no_switching() {
        let p = TechProfile::new(TechNode::Sky130, EdaFlow::Proprietary);
        assert_eq!(p.freq_at_voltage(500.0, p.vt - 0.01), 0.0);
    }

    #[test]
    fn energy_factor_quadratic() {
        let p = TechProfile::new(TechNode::Fin16, EdaFlow::Proprietary);
        assert!((p.energy_factor(p.vnom) - 1.0).abs() < 1e-12);
        assert!((p.energy_factor(p.vnom / 2.0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn four_corners() {
        assert_eq!(TechProfile::all_corners().len(), 4);
    }
}
