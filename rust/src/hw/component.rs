//! Datapath component library.
//!
//! Every normalizer unit is decomposed into instances of these components;
//! the synthesis estimator multiplies intrinsic costs (calibrated at the
//! 16nm-proprietary corner against DesignWare-class figures) by the
//! technology profile. Intrinsic numbers are per *operation* for energy
//! and per *instance* for area.

/// Component classes used by the three normalizer designs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Kind {
    /// Register bits (pipeline regs, I/O staging). `bits` = width.
    Reg,
    /// Register-file storage (small LUTs: the 2x16-entry ConSmax tables).
    RegFileBit,
    /// SRAM storage (score buffers; denser but slower than regfile).
    SramBit,
    /// Half-precision multiplier.
    FpMul16,
    /// Half-precision adder (accumulator datapath).
    FpAdd16,
    /// Single-precision multiplier.
    FpMul32,
    /// Single-precision adder / accumulator slice.
    FpAdd32,
    /// Single-precision divider (SRT-class, the Softmax normalize step).
    FpDiv32,
    /// Integer comparator (max search), 8-bit class.
    CmpInt8,
    /// FP comparator (running max on dequantized scores).
    CmpFp16,
    /// FP -> INT converter (ConSmax output stage).
    FpToInt,
    /// Fixed-function control / FSM overhead (per design).
    Control,
}

/// Intrinsic cost at the calibration corner.
#[derive(Debug, Clone, Copy)]
pub struct Intrinsic {
    /// µm² per instance (per bit for storage kinds).
    pub area_um2: f64,
    /// pJ per operation at nominal voltage (per-bit for storage kinds:
    /// read+write averaged).
    pub energy_pj: f64,
    /// Combinational delay through the component, ns (storage kinds:
    /// access time).
    pub delay_ns: f64,
}

impl Kind {
    /// Calibrated intrinsic costs at 16nm FinFET / proprietary flow.
    ///
    /// Sources for the calibration: published DesignWare FP datapath area
    /// in 16nm-class nodes (FP16 mult ≈ 200–300 µm², FP32 mult ≈ 4x FP16,
    /// SRT FP32 divide ≈ 10–15x FP16 mult), SRAM bitcell + periphery
    /// ≈ 0.15 µm²/bit for KB-class macros, regfile ≈ 0.5 µm²/bit, and
    /// switching energies in the 10–100 fJ range per 16-bit FP op.
    pub fn intrinsic(self) -> Intrinsic {
        match self {
            Kind::Reg => Intrinsic { area_um2: 1.2, energy_pj: 0.002, delay_ns: 0.05 },
            Kind::RegFileBit => Intrinsic { area_um2: 0.50, energy_pj: 0.0008, delay_ns: 0.25 },
            Kind::SramBit => Intrinsic { area_um2: 0.15, energy_pj: 0.0005, delay_ns: 0.45 },
            Kind::FpMul16 => Intrinsic { area_um2: 220.0, energy_pj: 0.055, delay_ns: 0.55 },
            Kind::FpAdd16 => Intrinsic { area_um2: 160.0, energy_pj: 0.040, delay_ns: 0.60 },
            Kind::FpMul32 => Intrinsic { area_um2: 850.0, energy_pj: 0.210, delay_ns: 0.75 },
            Kind::FpAdd32 => Intrinsic { area_um2: 420.0, energy_pj: 0.110, delay_ns: 0.80 },
            Kind::FpDiv32 => Intrinsic { area_um2: 2600.0, energy_pj: 0.900, delay_ns: 1.05 },
            Kind::CmpInt8 => Intrinsic { area_um2: 35.0, energy_pj: 0.004, delay_ns: 0.20 },
            Kind::CmpFp16 => Intrinsic { area_um2: 90.0, energy_pj: 0.012, delay_ns: 0.35 },
            Kind::FpToInt => Intrinsic { area_um2: 110.0, energy_pj: 0.018, delay_ns: 0.40 },
            Kind::Control => Intrinsic { area_um2: 120.0, energy_pj: 0.010, delay_ns: 0.30 },
        }
    }

    /// Component class for the Fig 9 area-breakdown buckets.
    pub fn breakdown_class(self) -> &'static str {
        match self {
            Kind::Reg | Kind::Control => "control+regs",
            Kind::RegFileBit | Kind::SramBit => "storage",
            Kind::FpMul16 | Kind::FpMul32 => "multipliers",
            Kind::FpAdd16 | Kind::FpAdd32 => "adders/accum",
            Kind::FpDiv32 => "divider",
            Kind::CmpInt8 | Kind::CmpFp16 => "comparators",
            Kind::FpToInt => "converters",
        }
    }
}

/// One component instance group in a netlist.
#[derive(Debug, Clone, Copy)]
pub struct Instance {
    pub kind: Kind,
    /// Instance count (bit count for storage kinds).
    pub count: f64,
    /// Activity factor: average operations per processed score element
    /// (storage kinds: accesses per element). This is what makes energy a
    /// per-element quantity.
    pub activity: f64,
    /// Whether the component sits on the clocked critical path.
    pub on_critical_path: bool,
}

impl Instance {
    pub fn new(kind: Kind, count: f64, activity: f64) -> Instance {
        Instance { kind, count, activity, on_critical_path: false }
    }

    pub fn critical(mut self) -> Instance {
        self.on_critical_path = true;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storage_density_ordering() {
        // SRAM must be denser than regfile, which is denser than flops.
        assert!(Kind::SramBit.intrinsic().area_um2 < Kind::RegFileBit.intrinsic().area_um2);
        assert!(Kind::RegFileBit.intrinsic().area_um2 < Kind::Reg.intrinsic().area_um2);
    }

    #[test]
    fn fp32_costs_more_than_fp16() {
        assert!(Kind::FpMul32.intrinsic().area_um2 > 2.0 * Kind::FpMul16.intrinsic().area_um2);
        assert!(Kind::FpAdd32.intrinsic().energy_pj > Kind::FpAdd16.intrinsic().energy_pj);
    }

    #[test]
    fn divider_dominates_multiplier() {
        let div = Kind::FpDiv32.intrinsic();
        let mul = Kind::FpMul32.intrinsic();
        assert!(div.area_um2 > 2.0 * mul.area_um2);
        assert!(div.delay_ns > mul.delay_ns);
    }

    #[test]
    fn all_kinds_have_positive_costs() {
        for k in [
            Kind::Reg, Kind::RegFileBit, Kind::SramBit, Kind::FpMul16,
            Kind::FpAdd16, Kind::FpMul32, Kind::FpAdd32, Kind::FpDiv32,
            Kind::CmpInt8, Kind::CmpFp16, Kind::FpToInt, Kind::Control,
        ] {
            let i = k.intrinsic();
            assert!(i.area_um2 > 0.0 && i.energy_pj > 0.0 && i.delay_ns > 0.0);
            assert!(!k.breakdown_class().is_empty());
        }
    }
}
