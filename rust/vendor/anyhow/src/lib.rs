//! Miniature re-implementation of the `anyhow` API surface this
//! repository uses, so the crate builds with no registry access (the
//! build environment is fully offline; see `rust/README.md`).
//!
//! Covered: [`Error`] (context chain, `{}` / `{:#}` / `{:?}` rendering),
//! [`Result`], the [`Context`] extension trait for `Result` and `Option`,
//! and the `anyhow!` / `bail!` / `ensure!` macros. Not covered (unused
//! here): downcasting, backtraces, `Error::new` from non-`Display`
//! payloads.

use std::fmt;

/// An error wrapping a chain of human-readable messages; `chain[0]` is
/// the outermost context, the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

/// `std::result::Result` specialized to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain on one line, outermost first.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (same trick as the real
// anyhow crate).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn context_chains_and_renders() {
        let r: Result<()> = Err(io_err()).context("opening config");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing thing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let _ok: Result<u32> = Ok::<u32, Error>(1).with_context(|| {
            called = true;
            "ctx"
        });
        assert!(!called);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(anyhow!("plain").to_string(), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<String> {
            let bytes = vec![0xFFu8];
            let s = std::str::from_utf8(&bytes)?;
            Ok(s.to_string())
        }
        assert!(g().is_err());
    }
}
