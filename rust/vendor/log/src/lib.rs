//! Miniature re-implementation of the `log` crate facade used by this
//! repository (offline build; see `rust/README.md`): the `error!` /
//! `warn!` / `info!` / `debug!` / `trace!` macros, the [`Log`] trait, a
//! global logger slot and the [`LevelFilter`] machinery. Structured
//! key-values, module-path targets and per-target filtering are not
//! implemented (unused here).

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single record. Lower numeric value = more severe.
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

/// Maximum-verbosity filter. `Off` disables everything.
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        })
    }
}

/// Metadata about a record (just the level in this miniature).
#[derive(Debug, Clone, Copy)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log record: level + preformatted arguments.
pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink. Mirrors `log::Log`.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __private_log(level: Level, args: fmt::Arguments<'_>) {
    if level <= max_level() {
        if let Some(logger) = LOGGER.get() {
            let record = Record { metadata: Metadata { level }, args };
            if logger.enabled(record.metadata()) {
                logger.log(&record);
            }
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Info <= LevelFilter::Debug);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Error <= LevelFilter::Error);
        assert!(!(Level::Error <= LevelFilter::Off));
    }

    // single test for everything touching the global state, so parallel
    // test threads never race on MAX_LEVEL
    #[test]
    fn global_state_roundtrip_and_noop_logging() {
        set_max_level(LevelFilter::Warn);
        assert_eq!(max_level(), LevelFilter::Warn);
        set_max_level(LevelFilter::Trace);
        assert_eq!(max_level(), LevelFilter::Trace);
        // no logger installed: must not panic
        info!("nothing listens to {}", 42);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
