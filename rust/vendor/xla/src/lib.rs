//! Stub of the `xla` (xla-rs) API surface used by `consmax`'s PJRT
//! engine (`--features pjrt`).
//!
//! Purpose: the build environment has no network and no
//! `libxla_extension`, but the engine, trainer and server code should
//! still *type-check* under `--features pjrt` so the AOT path cannot rot.
//! This crate mirrors the exact subset of xla-rs types and signatures the
//! repo calls. Host-side [`Literal`] storage is real (create / ty /
//! shape / to_vec round-trip); everything touching the PJRT runtime
//! ([`PjRtClient::cpu`], compilation, buffers) returns a descriptive
//! [`Error`].
//!
//! To execute artifacts for real, replace this directory with a checkout
//! of `LaurentMazare/xla-rs` (the package is also named `xla`) and set
//! `XLA_EXTENSION_DIR`; no source change in `consmax` is needed.

use std::fmt;

/// Error type mirroring `xla::Error` (std-error so `anyhow` can wrap it).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err(what: &str) -> Error {
    Error(format!(
        "{what}: the vendored `xla` stub has no PJRT runtime; replace \
         rust/vendor/xla with a real xla-rs checkout to execute artifacts \
         (see rust/README.md §PJRT)"
    ))
}

/// Element types of the artifact tensors (subset of xla-rs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    F16,
    Bf16,
    F32,
    F64,
}

/// Primitive types for `Literal::convert` (subset of xla-rs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F16,
    Bf16,
    F32,
    F64,
    S32,
}

/// Plain-old-data element types a [`Literal`] can expose as a typed vec.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
    fn write_le(self, out: &mut Vec<u8>);
}

macro_rules! native {
    ($ty:ty, $et:expr) => {
        impl NativeType for $ty {
            const ELEMENT_TYPE: ElementType = $et;
            fn from_le(bytes: &[u8]) -> Self {
                let mut buf = [0u8; std::mem::size_of::<$ty>()];
                buf.copy_from_slice(bytes);
                <$ty>::from_le_bytes(buf)
            }
            fn write_le(self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
        }
    };
}

native!(f32, ElementType::F32);
native!(f64, ElementType::F64);
native!(i32, ElementType::S32);
native!(i8, ElementType::S8);
native!(u8, ElementType::U8);

fn element_size(ty: ElementType) -> usize {
    match ty {
        ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
        ElementType::F16 | ElementType::Bf16 => 2,
        ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
        ElementType::S64 | ElementType::F64 => 8,
    }
}

/// Array shape of a literal: dims as i64, like xla-rs.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side literal with real storage (dtype + shape + little-endian
/// bytes), so marshalling code round-trips even on the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if data.len() != elems * element_size(ty) {
            return Err(Error(format!(
                "literal data length {} != {} elements of {ty:?}",
                data.len(),
                elems
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
        })
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::ELEMENT_TYPE {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        let size = element_size(self.ty);
        Ok(self.data.chunks_exact(size).map(T::from_le).collect())
    }

    /// Dtype conversion requires the real XLA runtime.
    pub fn convert(&self, to: PrimitiveType) -> Result<Literal> {
        Err(stub_err(&format!("Literal::convert({to:?})")))
    }

    /// Tuple decomposition requires the real XLA runtime (stub literals
    /// are always arrays).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(stub_err("Literal::to_tuple"))
    }
}

/// Parsed HLO module (opaque; real parsing needs xla_extension).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(stub_err(&format!("HloModuleProto::from_text_file({path:?})")))
    }
}

/// An XLA computation built from a module proto.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device handle (never constructed by the stub).
pub struct PjRtDevice(());

/// Device buffer (never constructed by the stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (never constructed by the stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client. `cpu()` fails on the stub with a pointer at the docs.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub_err("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(stub_err("PjRtClient::buffer_from_host_literal"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(stub_err("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let mut bytes = Vec::new();
        for v in vals {
            v.write_le(&mut bytes);
        }
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &bytes,
        )
        .unwrap();
        assert_eq!(lit.ty().unwrap(), ElementType::F32);
        assert_eq!(lit.array_shape().unwrap().dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn runtime_entry_points_error_helpfully() {
        let err = PjRtClient::cpu().err().unwrap().to_string();
        assert!(err.contains("stub"), "{err}");
        assert!(err.contains("README"), "{err}");
    }

    #[test]
    fn length_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0u8; 4]
        )
        .is_err());
    }
}
