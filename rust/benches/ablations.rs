//! Ablations over the paper's design choices:
//!
//! 1. **Bitwidth-split vs monolithic LUT** (§IV-A1): the 2×16-entry
//!    nibble-split table vs a flat 256-entry table — area/energy of the
//!    storage, and the accuracy cost (none, both are exact on the grid).
//! 2. **Reduction unit vs native wide LUT** (§IV-A2): INT16 support via
//!    chained 8-bit units vs a hypothetical 64Ki-entry table.
//! 3. **Tensor-core lane balance**: how the Fig 5 saving responds when
//!    QK/PV lanes are unbalanced (the element-wise pipeline tolerates
//!    skew; the token pipeline's barrier amplifies it).
//! 4. **Partial-softmax chunk count**: FlashAttention-style chunking
//!    reduces buffer pressure but the sync cost is flat — more chunks
//!    don't remove the barrier (the paper's Fig 3b argument).
//!
//! Run: `cargo bench --bench ablations`

use consmax::hw::component::{Instance, Kind};
use consmax::hw::designs::UnitDesign;
use consmax::hw::{consmax_unit, EdaFlow, Precision, Synthesizer, TechNode, TechProfile};
use consmax::sim::{simulate, NormKind, Schedule, Workload};
use consmax::util::bench::print_table;

/// ConSmax unit variant with a monolithic 256-entry LUT (no nibble split).
fn consmax_monolithic() -> UnitDesign {
    UnitDesign {
        name: "ConSmax-mono256".into(),
        instances: vec![
            // one 256-entry x 16b table, one read per element
            Instance::new(Kind::RegFileBit, 256.0 * 16.0, 1.0).critical(),
            // only the C multiplier remains (no merge multiply)
            Instance::new(Kind::FpMul16, 1.0, 1.0).critical(),
            Instance::new(Kind::FpToInt, 1.0, 1.0),
            Instance::new(Kind::Reg, 8.0 + 16.0 * 2.0, 3.0),
            Instance::new(Kind::Control, 1.0, 1.0),
        ],
        elems_per_cycle: 1.0,
    }
}

/// Hypothetical INT16-native unit: a 64Ki-entry table (what the
/// reduction unit avoids).
fn consmax_int16_native() -> UnitDesign {
    UnitDesign {
        name: "ConSmax-16b-native".into(),
        instances: vec![
            Instance::new(Kind::SramBit, 65536.0 * 16.0, 1.0).critical(),
            Instance::new(Kind::FpMul16, 1.0, 1.0).critical(),
            Instance::new(Kind::FpToInt, 1.0, 1.0),
            Instance::new(Kind::Reg, 16.0 + 16.0 * 2.0, 3.0),
            Instance::new(Kind::Control, 1.0, 1.0),
        ],
        elems_per_cycle: 1.0,
    }
}

fn main() {
    let synth = Synthesizer::new(TechProfile::new(TechNode::Fin16, EdaFlow::Proprietary));

    // ---- ablation 1 + 2: LUT organization -------------------------------
    let designs = [
        consmax_unit(Precision::Int8),
        consmax_monolithic(),
        consmax_unit(Precision::Int16),
        consmax_int16_native(),
    ];
    let rows: Vec<Vec<String>> = designs
        .iter()
        .map(|d| {
            let r = synth.synthesize(d);
            let lut_bits: f64 = d
                .instances
                .iter()
                .filter(|i| matches!(i.kind, Kind::RegFileBit | Kind::SramBit))
                .map(|i| i.count)
                .sum();
            vec![
                d.name.clone(),
                format!("{lut_bits:.0}"),
                format!("{:.5}", r.area_mm2),
                format!("{:.3}", r.energy_pj_per_elem_nominal),
                format!("{:.0}", r.fmax_mhz),
            ]
        })
        .collect();
    print_table(
        "Ablation 1/2: LUT organization (split keeps 8b storage at 512 bits \
         for identical exactness; 16b native would need 1 Mib)",
        &["design", "LUT bits", "area mm2", "E pJ/elem", "Fmax MHz"],
        &rows,
    );

    // ---- ablation 3: lane balance ---------------------------------------
    let mut rows = Vec::new();
    for (qk, pv) in [(64usize, 64usize), (64, 16), (16, 64), (16, 16)] {
        let w = Workload {
            tokens: 1,
            seq: 1024,
            head_dim: 64,
            qk_lanes: qk,
            pv_lanes: pv,
            norm_latency: 4,
        };
        let sm = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
        let cs = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
        rows.push(vec![
            format!("{qk}/{pv}"),
            sm.total_cycles.to_string(),
            cs.total_cycles.to_string(),
            format!("{:.1}%", (1.0 - cs.total_cycles as f64 / sm.total_cycles as f64) * 100.0),
        ]);
    }
    print_table(
        "Ablation 3: QK/PV lane skew, seq 1024 (element-wise overlaps the slow \
         side; token pipeline serializes it)",
        &["qk/pv lanes", "Softmax cyc", "ConSmax cyc", "saving"],
        &rows,
    );

    // ---- ablation 4: partial-softmax chunk count -------------------------
    let mut rows = Vec::new();
    let w = Workload::paper_generation(1024);
    let cs = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
    for chunks in [1usize, 2, 4, 8, 16, 64] {
        let ps = simulate(&w, NormKind::PartialSoftmax { chunks }, Schedule::TokenPipeline);
        rows.push(vec![
            chunks.to_string(),
            ps.total_cycles.to_string(),
            format!("{:.2}x", ps.total_cycles as f64 / cs.total_cycles as f64),
        ]);
    }
    print_table(
        "Ablation 4: partial-softmax chunking never closes the gap — the \
         global sync survives any chunk count (Fig 3b)",
        &["chunks", "cycles", "vs ConSmax"],
        &rows,
    );
}
