//! Int8 accuracy gate: the paper's "comparable accuracy" claim as a
//! CI-enforced number (EXPERIMENTS.md §Quantized serving accuracy,
//! DESIGN.md §Quantization seam).
//!
//! Run: `cargo bench --bench quant_gate` (native, no artifacts). For
//! each normalizer the in-tree validation corpus is scored twice with
//! the same weights — the f32 serving path, then the int8 path
//! (per-channel int8 projections + LM head, and for ConSmax the
//! bit-split LUT attention tail) — and the gate fails unless the loss
//! moves by less than [`DELTA_GATE_NATS`] nats.
//!
//! Emits `BENCH_quant.json` and exits non-zero when any normalizer
//! breaches the gate, so `make artifacts` / CI cannot ship a quantized
//! serving path that silently lost accuracy.

use consmax::config::{ModelConfig, QuantMode};
use consmax::coordinator::ParamStore;
use consmax::data::{ByteTokenizer, Corpus};
use consmax::metrics::perplexity;
use consmax::runtime::backend::NativeModel;
use consmax::util::bench::print_table;
use consmax::util::json::Json;

/// Validation batches scored per normalizer (same count as `eval`).
const EVAL_BATCHES: usize = 8;
/// Accuracy gate: |int8 loss − f32 loss| must stay under this many
/// nats. Per-channel pow2-scaled int8 weights carry ≤ scale/2 error per
/// element and the LUT tail quantizes scores at the paper's 1/16
/// resolution, so the drift on the in-tree corpus sits well under this
/// bound; breaching it means the quantization seam regressed.
const DELTA_GATE_NATS: f64 = 0.25;

struct GateRow {
    normalizer: &'static str,
    f32_loss: f64,
    int8_loss: f64,
}

impl GateRow {
    fn delta(&self) -> f64 {
        self.int8_loss - self.f32_loss
    }
}

fn eval_loss(model: &NativeModel, cfg: &ModelConfig) -> anyhow::Result<f64> {
    let corpus = Corpus::tiny();
    let (_, val_text) = corpus.split();
    let val = consmax::data::BatchSampler::new(
        ByteTokenizer.encode(val_text),
        cfg.train_batch,
        cfg.ctx,
        0,
    );
    let batches = val.eval_batches(EVAL_BATCHES);
    anyhow::ensure!(!batches.is_empty(), "validation stream too small");
    let mut total = 0.0;
    for (x, y) in &batches {
        total += model.loss(x, y, cfg.train_batch, cfg.ctx)?;
    }
    Ok(total / batches.len() as f64)
}

fn main() -> anyhow::Result<()> {
    let mut rows = Vec::new();
    for normalizer in ["consmax", "softmax", "softermax"] {
        let cfg = ModelConfig::builtin("tiny", normalizer)?;
        let store = ParamStore::init(&cfg, 0)?;
        let f32_model =
            NativeModel::from_params(&cfg, &store.order, &store.params)?;
        let int8_model = NativeModel::from_params_quant(
            &cfg,
            &store.order,
            &store.params,
            QuantMode::Int8,
        )?;
        rows.push(GateRow {
            normalizer,
            f32_loss: eval_loss(&f32_model, &cfg)?,
            int8_loss: eval_loss(&int8_model, &cfg)?,
        });
    }

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.normalizer.to_string(),
                format!("{:.4}", r.f32_loss),
                format!("{:.4}", r.int8_loss),
                format!("{:+.4}", r.delta()),
                format!("{:.2}", perplexity(r.f32_loss)),
                format!("{:.2}", perplexity(r.int8_loss)),
            ]
        })
        .collect();
    print_table(
        &format!(
            "Int8 accuracy gate, tiny configs ({EVAL_BATCHES} val batches, \
             gate |delta| < {DELTA_GATE_NATS} nats)"
        ),
        &["normalizer", "f32 loss", "int8 loss", "delta", "f32 ppl",
          "int8 ppl"],
        &table,
    );

    let mut pairs = vec![
        ("bench".to_string(), Json::from("quant")),
        ("eval_batches".to_string(), Json::from(EVAL_BATCHES)),
        ("delta_gate_nats".to_string(), Json::from(DELTA_GATE_NATS)),
        (
            "threads".to_string(),
            Json::from(consmax::runtime::parallel::current_threads()),
        ),
    ];
    for r in &rows {
        pairs.push((
            r.normalizer.to_string(),
            Json::from_pairs([
                ("f32_loss".to_string(), Json::from(r.f32_loss)),
                ("int8_loss".to_string(), Json::from(r.int8_loss)),
                ("delta_nats".to_string(), Json::from(r.delta())),
                ("f32_ppl".to_string(), Json::from(perplexity(r.f32_loss))),
                ("int8_ppl".to_string(), Json::from(perplexity(r.int8_loss))),
            ]),
        ));
    }
    let doc = Json::from_pairs(pairs);
    std::fs::write("BENCH_quant.json", doc.to_string())?;
    println!("\nwrote BENCH_quant.json");

    let breaches: Vec<&GateRow> = rows
        .iter()
        .filter(|r| !(r.delta().abs() < DELTA_GATE_NATS))
        .collect();
    if !breaches.is_empty() {
        for r in &breaches {
            eprintln!(
                "FAIL: {} int8-vs-f32 loss delta {:+.4} nats breaches the \
                 {DELTA_GATE_NATS}-nat gate — the paper's 'comparable \
                 accuracy' claim no longer holds on this path",
                r.normalizer,
                r.delta()
            );
        }
        std::process::exit(1);
    }
    println!(
        "PASS: every int8-vs-f32 loss delta within {DELTA_GATE_NATS} nats"
    );
    Ok(())
}
