//! Bench: regenerate **Fig 5** — pipeline time savings of
//! synchronization-free ConSmax — across context lengths and token
//! counts, plus simulator throughput.
//!
//! Run: `cargo bench --bench fig5_pipeline`

use consmax::sim::pipeline::fig5_time_saving;
use consmax::sim::{simulate, NormKind, Schedule, Workload};
use consmax::util::bench::{print_table, Bencher};

fn main() {
    // generation-stage latency per normalizer across context sizes
    let mut rows = Vec::new();
    for seq in [256usize, 512, 1024, 2048, 4096, 8192, 16384] {
        let w = Workload::paper_generation(seq);
        let sm = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
        let so = simulate(&w, NormKind::Softermax, Schedule::TokenPipeline);
        let ps = simulate(&w, NormKind::PartialSoftmax { chunks: 8 }, Schedule::TokenPipeline);
        let cs = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
        rows.push(vec![
            seq.to_string(),
            sm.total_cycles.to_string(),
            so.total_cycles.to_string(),
            ps.total_cycles.to_string(),
            cs.total_cycles.to_string(),
            format!("{:.1}%", (1.0 - cs.total_cycles as f64 / sm.total_cycles as f64) * 100.0),
            format!("{:.0}% vs {:.0}%", cs.utilization() * 100.0, sm.utilization() * 100.0),
        ]);
    }
    print_table(
        "Fig 5: single-token generation latency (cycles) and time saving; \
         utilization ConSmax-vs-Softmax",
        &["seq", "Softmax", "Softermax", "Partial/8", "ConSmax", "saving", "util"],
        &rows,
    );

    // multi-token summarization
    let mut rows = Vec::new();
    for tokens in [1usize, 8, 32, 128] {
        let (base, cons, saving) = {
            let w = Workload::summarization(tokens, 256);
            let b = simulate(&w, NormKind::Softmax, Schedule::TokenPipeline);
            let c = simulate(&w, NormKind::ConSmax, Schedule::ElementWise);
            let s = 1.0 - c.total_cycles as f64 / b.total_cycles as f64;
            (b, c, s)
        };
        rows.push(vec![
            tokens.to_string(),
            base.total_cycles.to_string(),
            cons.total_cycles.to_string(),
            format!("{:.1}%", saving * 100.0),
        ]);
    }
    print_table(
        "Summarization stage: savings persist under token-level overlap",
        &["tokens", "Softmax", "ConSmax", "saving"],
        &rows,
    );

    println!();
    let mut b = Bencher::new();
    b.bench("simulate gen seq=256", || fig5_time_saving(256));
    b.bench("simulate gen seq=4096", || fig5_time_saving(4096));
    b.bench("simulate summarization 128 tok", || {
        let w = Workload::summarization(128, 256);
        simulate(&w, NormKind::Softmax, Schedule::TokenPipeline)
    });
}
