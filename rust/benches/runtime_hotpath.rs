//! Bench: the L3 hot paths — PJRT kernel dispatch (ConSmax vs Softmax vs
//! the LUT path), KV-cached decode step, literal marshalling, and the
//! bit-exact software LUT. This is the §Perf workhorse.
//!
//! Run: `cargo bench --bench runtime_hotpath` (needs `make artifacts`)

use consmax::coordinator::ParamStore;
use consmax::quant::{merge_beta_gamma, BitSplitLut, Int8Quantizer};
use consmax::runtime::{DType, Engine, HostTensor};
use consmax::util::bench::Bencher;
use consmax::util::rng::Pcg32;

fn main() {
    let Ok(engine) = Engine::new("artifacts") else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let mut b = Bencher::new();
    let mut rng = Pcg32::seeded(0);

    // ---- normalizer kernels over a (64, 256) score block ---------------
    let n = 64 * 256;
    let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let c = vec![(-1.5f32).exp() / 100.0; n];
    let s_t = HostTensor::from_f32(&scores, &[64, 256]);
    let c_t = HostTensor::from_f32(&c, &[64, 256]);

    // warm the executable cache outside the timed region
    engine.execute("op_consmax", &[s_t.clone(), c_t.clone()]).unwrap();
    engine.execute("op_softmax", std::slice::from_ref(&s_t)).unwrap();
    engine.execute("op_softermax", std::slice::from_ref(&s_t)).unwrap();

    let st = b.bench("op_consmax (64x256) via PJRT", || {
        engine.execute("op_consmax", &[s_t.clone(), c_t.clone()]).unwrap()
    });
    println!("    -> {:.1} Melem/s", st.throughput(n as f64) / 1e6);
    b.bench("op_softmax (64x256) via PJRT", || {
        engine.execute("op_softmax", std::slice::from_ref(&s_t)).unwrap()
    });
    b.bench("op_softermax (64x256) via PJRT", || {
        engine.execute("op_softermax", std::slice::from_ref(&s_t)).unwrap()
    });

    // ---- INT8 LUT path: AOT kernel vs native Rust model -----------------
    let qs: Vec<i8> = (0..n).map(|_| (rng.next_u32() & 0xFF) as u8 as i8).collect();
    let q_t = HostTensor::from_i8(&qs, &[64, 256]);
    engine.execute("op_lut_consmax", &[q_t.clone(), c_t.clone()]).unwrap();
    b.bench("op_lut_consmax (64x256) via PJRT", || {
        engine.execute("op_lut_consmax", &[q_t.clone(), c_t.clone()]).unwrap()
    });
    let lut = BitSplitLut::paper();
    let chw = merge_beta_gamma(1.5, 100.0);
    let st = b.bench("BitSplitLut::consmax 16k elems (native)", || {
        lut.consmax_slice(&qs, chw)
    });
    println!("    -> {:.1} Melem/s", st.throughput(n as f64) / 1e6);
    let quant = Int8Quantizer::paper();
    b.bench("Int8Quantizer 16k elems", || quant.quantize_slice(&scores));

    // ---- fused consmax+PV tail ------------------------------------------
    let s256: Vec<f32> = (0..256 * 256).map(|_| rng.normal() as f32).collect();
    let c256 = vec![0.01f32; 256 * 256];
    let v: Vec<f32> = (0..256 * 64).map(|_| rng.normal() as f32).collect();
    let spv = [
        HostTensor::from_f32(&s256, &[256, 256]),
        HostTensor::from_f32(&c256, &[256, 256]),
        HostTensor::from_f32(&v, &[256, 64]),
    ];
    engine.execute("op_consmax_pv", &spv).unwrap();
    b.bench("op_consmax_pv (256x256 @ 64) via PJRT", || {
        engine.execute("op_consmax_pv", &spv).unwrap()
    });

    // ---- marshalling ------------------------------------------------------
    b.bench("HostTensor->Literal (64KiB f32)", || s_t.to_literal().unwrap());
    let lit = s_t.to_literal().unwrap();
    b.bench("Literal->HostTensor (64KiB f32)", || {
        HostTensor::from_literal(&lit).unwrap()
    });

    // ---- decode step (tiny model, the serving inner loop) ----------------
    if let Ok(cfg) = engine.manifest.config("tiny_consmax") {
        let cfg = cfg.clone();
        let store = ParamStore::init(&cfg, 0).unwrap();
        let params: Vec<xla::Literal> =
            store.params.iter().map(|t| t.to_literal().unwrap()).collect();
        let shape = vec![cfg.n_layer, 1, cfg.n_head, cfg.ctx, cfg.head_dim()];
        let kc = HostTensor::zeros(DType::F32, &shape).to_literal().unwrap();
        let vc = HostTensor::zeros(DType::F32, &shape).to_literal().unwrap();
        let pos = HostTensor::scalar_i32(0).to_literal().unwrap();
        let tok = HostTensor::from_i32(&[65], &[1]).to_literal().unwrap();
        let entry = "tiny_consmax_decode_b1";
        let exe = engine.load(entry).unwrap();
        let inputs: Vec<&xla::Literal> =
            params.iter().chain([&kc, &vc, &pos, &tok]).collect();
        engine.execute_literal_refs(entry, &exe, &inputs).unwrap();
        let st = b.bench("decode_b1 step (per-call param upload)", || {
            engine.execute_literal_refs(entry, &exe, &inputs).unwrap()
        });
        println!(
            "    -> {:.0} tok/s single-stream ceiling",
            1e9 / st.median_ns
        );
        // serving path: params uploaded once, reused as device buffers
        let pbufs: Vec<xla::PjRtBuffer> =
            store.params.iter().map(|t| engine.upload(t).unwrap()).collect();
        let kcb = engine.upload_literal(&kc).unwrap();
        let vcb = engine.upload_literal(&vc).unwrap();
        let posb = engine.upload_literal(&pos).unwrap();
        let tokb = engine.upload_literal(&tok).unwrap();
        let binputs: Vec<&xla::PjRtBuffer> =
            pbufs.iter().chain([&kcb, &vcb, &posb, &tokb]).collect();
        engine.execute_buffer_refs(entry, &exe, &binputs).unwrap();
        let st = b.bench("decode_b1 step (cached param buffers)", || {
            engine.execute_buffer_refs(entry, &exe, &binputs).unwrap()
        });
        println!(
            "    -> {:.0} tok/s single-stream ceiling",
            1e9 / st.median_ns
        );
    }

    // ---- end-to-end train step (tiny) -------------------------------------
    if let Ok(cfg) = engine.manifest.config("tiny_consmax") {
        let cfg = cfg.clone();
        let store = ParamStore::init(&cfg, 0).unwrap();
        let mut state: Vec<xla::Literal> = Vec::new();
        for group in [&store.params, &store.m, &store.v] {
            for t in group {
                state.push(t.to_literal().unwrap());
            }
        }
        let x = HostTensor::from_i32(
            &vec![1; cfg.train_batch * cfg.ctx],
            &[cfg.train_batch, cfg.ctx],
        )
        .to_literal()
        .unwrap();
        let stp = HostTensor::scalar_f32(0.0).to_literal().unwrap();
        let entry = "tiny_consmax_train_step";
        let exe = engine.load(entry).unwrap();
        let inputs: Vec<&xla::Literal> =
            state.iter().chain([&stp, &x, &x]).collect();
        engine.execute_literal_refs(entry, &exe, &inputs).unwrap();
        let mut bc = Bencher::coarse();
        let st = bc.bench("train_step (tiny, fused fwd+bwd+AdamW)", || {
            engine.execute_literal_refs(entry, &exe, &inputs).unwrap()
        });
        println!("    -> {:.1} steps/s", 1e9 / st.median_ns);
    }
}
