//! KV-memory scaling: resident concurrency at a **fixed byte budget**,
//! dense f32 per-row caches vs the paged pool with fp16 storage
//! (EXPERIMENTS.md §KV memory scaling, DESIGN.md §KV-memory seam).
//!
//! Run: `cargo bench --bench kv_bench` (native, no artifacts). One
//! saturating greedy workload (every request submitted up front) is
//! served three ways under the same KV byte budget:
//!
//! * **dense f32** — the budget buys `budget / dense_row_bytes` whole
//!   rows; the slot pool is capped there (the pre-paging memory model:
//!   every slot pre-reserves a full `ctx` row);
//! * **paged f32** — same bytes as a block pool: short rows stop
//!   wasting the tail of their reservation;
//! * **paged f16** — half the bytes per token on top;
//! * **paged int8** — one byte per element plus per-vector scales
//!   (`--kv-dtype int8`, DESIGN.md §Quantization seam).
//!
//! Emits `BENCH_kv.json` and exits non-zero unless paged-f16 holds
//! **≥ 2× the dense resident concurrency** at the same budget, paged
//! int8 holds **≥ 3.5×**, each at tokens/s no worse than
//! [`TOKS_FLOOR`]× dense (equal within noise — the correctness suites
//! pin paged-f32 bitwise to dense, and fp16/bf16/int8 to their
//! documented tolerances). CI smoke-runs this so the artifact and the
//! memory-scaling claims cannot rot.

use std::time::Instant;

use consmax::config::{KvCacheConfig, KvDtype, ModelConfig};
use consmax::coordinator::{GenRequest, Generator, ParamStore, Server};
use consmax::util::bench::print_table;
use consmax::util::json::Json;

/// Saturating request count (all submitted before the first step).
const N_REQUESTS: usize = 32;
/// Prompt length in byte-tokens (clamp-free: < ctx - MAX_NEW).
const PROMPT_TOKENS: usize = 30;
/// Greedy tokens generated per request.
const MAX_NEW: usize = 8;
/// Budget in dense rows: the dense baseline serves exactly this many
/// co-resident requests, and the paged pools get the same bytes.
const DENSE_ROWS: usize = 4;
/// Paged block size in tokens.
const BLOCK_TOKENS: usize = 16;
/// Residency floor: paged-f16 must hold at least this multiple of the
/// dense baseline's peak co-resident requests (acceptance criterion).
const RESIDENCY_FLOOR: f64 = 2.0;
/// Residency floor for paged int8: ~4× fewer payload bytes per token
/// than f32 minus the per-vector scale overhead.
const INT8_RESIDENCY_FLOOR: f64 = 3.5;
/// Throughput guard: each paged layout's tok/s must stay within noise
/// of dense.
const TOKS_FLOOR: f64 = 0.6;

struct RunStats {
    label: String,
    peak_resident: usize,
    tok_s: f64,
    wall_s: f64,
    tokens: u64,
    preemptions: u64,
    kv_blocks: usize,
    kv_shared_peak: usize,
}

fn workload() -> Vec<GenRequest> {
    let prompt: String = "the paged kv cache block pool "
        .chars()
        .cycle()
        .take(PROMPT_TOKENS)
        .collect();
    (0..N_REQUESTS as u64)
        .map(|id| GenRequest {
            id,
            prompt: prompt.clone(),
            max_new_tokens: MAX_NEW,
            temperature: 0.0,
            stop: None,
            deadline_ms: None,
        })
        .collect()
}

fn run(
    cfg: &ModelConfig,
    store: &ParamStore,
    label: &str,
    kv: Option<KvCacheConfig>,
    slots: usize,
) -> anyhow::Result<RunStats> {
    let mut server = Server::new(Generator::native(cfg, store, 7)?);
    server.set_kv_config(kv)?;
    server.set_max_batch(slots)?;
    for req in workload() {
        server.submit(req);
    }
    let mut peak = 0usize;
    let mut shared_peak = 0usize;
    let t0 = Instant::now();
    while server.pending() > 0 || server.in_flight() > 0 {
        server.step()?;
        peak = peak.max(server.in_flight());
        let st = server.stats();
        shared_peak = shared_peak.max(st.kv_shared_blocks);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let st = server.stats();
    Ok(RunStats {
        label: label.to_string(),
        peak_resident: peak,
        tok_s: server.tokens_out as f64 / wall_s,
        wall_s,
        tokens: server.tokens_out,
        preemptions: st.preemptions,
        kv_blocks: st.kv_total_blocks,
        kv_shared_peak: shared_peak,
    })
}

fn stats_json(s: &RunStats) -> Json {
    Json::from_pairs([
        ("peak_resident".to_string(), Json::from(s.peak_resident)),
        ("tok_s".to_string(), Json::from(s.tok_s)),
        ("wall_s".to_string(), Json::from(s.wall_s)),
        ("tokens".to_string(), Json::from(s.tokens as f64)),
        ("preemptions".to_string(), Json::from(s.preemptions as f64)),
        ("kv_blocks".to_string(), Json::from(s.kv_blocks)),
        ("kv_shared_peak".to_string(), Json::from(s.kv_shared_peak)),
    ])
}

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::builtin("tiny", "consmax")?;
    let store = ParamStore::init(&cfg, 0)?;

    // one dense row's K+V bytes: the unit the budget is expressed in
    let dense_row_bytes =
        2 * cfg.n_layer * cfg.n_head * cfg.ctx * cfg.head_dim() * 4;
    let budget = DENSE_ROWS * dense_row_bytes;

    let paged = |dtype: KvDtype| KvCacheConfig {
        dtype,
        block_tokens: BLOCK_TOKENS,
        mem_bytes: Some(budget),
    };

    let dense = run(&cfg, &store, "dense f32", None, DENSE_ROWS)?;
    let paged32 = run(
        &cfg,
        &store,
        "paged f32",
        Some(paged(KvDtype::F32)),
        N_REQUESTS,
    )?;
    let paged16 = run(
        &cfg,
        &store,
        "paged f16",
        Some(paged(KvDtype::F16)),
        N_REQUESTS,
    )?;
    let paged8 = run(
        &cfg,
        &store,
        "paged int8",
        Some(paged(KvDtype::Int8)),
        N_REQUESTS,
    )?;

    let residency_ratio = paged16.peak_resident as f64 / dense.peak_resident as f64;
    let toks_ratio = paged16.tok_s / dense.tok_s;
    let i8_residency_ratio =
        paged8.peak_resident as f64 / dense.peak_resident as f64;
    let i8_toks_ratio = paged8.tok_s / dense.tok_s;

    let row = |s: &RunStats| {
        vec![
            s.label.clone(),
            format!("{}", s.peak_resident),
            format!("{:.0}", s.tok_s),
            format!("{}", s.kv_blocks),
            format!("{}", s.kv_shared_peak),
            format!("{}", s.preemptions),
        ]
    };
    print_table(
        &format!(
            "KV memory scaling, {} ({} reqs of {}+{} tokens, budget = {} \
             dense rows = {} KiB)",
            cfg.key,
            N_REQUESTS,
            PROMPT_TOKENS,
            MAX_NEW,
            DENSE_ROWS,
            budget / 1024
        ),
        &["layout", "peak resident", "tok/s", "blocks", "shared peak",
          "preempts"],
        &[row(&dense), row(&paged32), row(&paged16), row(&paged8)],
    );
    println!(
        "\npaged-f16/dense resident concurrency at fixed memory: \
         {residency_ratio:.2}x (floor {RESIDENCY_FLOOR}x); tok/s ratio \
         {toks_ratio:.2} (floor {TOKS_FLOOR})"
    );
    println!(
        "paged-int8/dense resident concurrency at fixed memory: \
         {i8_residency_ratio:.2}x (floor {INT8_RESIDENCY_FLOOR}x); tok/s \
         ratio {i8_toks_ratio:.2} (floor {TOKS_FLOOR})"
    );

    let doc = Json::from_pairs([
        ("bench".to_string(), Json::from("kv")),
        ("config".to_string(), Json::from(cfg.key.as_str())),
        ("normalizer".to_string(), Json::from(cfg.normalizer.as_str())),
        ("requests".to_string(), Json::from(N_REQUESTS)),
        ("prompt_tokens".to_string(), Json::from(PROMPT_TOKENS)),
        ("max_new".to_string(), Json::from(MAX_NEW)),
        ("budget_bytes".to_string(), Json::from(budget)),
        ("dense_row_bytes".to_string(), Json::from(dense_row_bytes)),
        ("block_tokens".to_string(), Json::from(BLOCK_TOKENS)),
        (
            "threads".to_string(),
            Json::from(consmax::runtime::parallel::current_threads()),
        ),
        ("dense".to_string(), stats_json(&dense)),
        ("paged_f32".to_string(), stats_json(&paged32)),
        ("paged_f16".to_string(), stats_json(&paged16)),
        ("paged_int8".to_string(), stats_json(&paged8)),
        ("residency_ratio".to_string(), Json::from(residency_ratio)),
        (
            "min_residency_required".to_string(),
            Json::from(RESIDENCY_FLOOR),
        ),
        ("toks_ratio".to_string(), Json::from(toks_ratio)),
        ("min_toks_ratio_required".to_string(), Json::from(TOKS_FLOOR)),
        (
            "int8_residency_ratio".to_string(),
            Json::from(i8_residency_ratio),
        ),
        (
            "min_int8_residency_required".to_string(),
            Json::from(INT8_RESIDENCY_FLOOR),
        ),
        ("int8_toks_ratio".to_string(), Json::from(i8_toks_ratio)),
    ]);
    std::fs::write("BENCH_kv.json", doc.to_string())?;
    println!("wrote BENCH_kv.json");

    if residency_ratio < RESIDENCY_FLOOR || toks_ratio < TOKS_FLOOR {
        eprintln!(
            "FAIL: fp16 paging must hold >= {RESIDENCY_FLOOR}x dense \
             resident requests at fixed memory without dropping below \
             {TOKS_FLOOR}x dense tok/s (got {residency_ratio:.2}x, \
             {toks_ratio:.2}) — see table above"
        );
        std::process::exit(1);
    }
    if i8_residency_ratio < INT8_RESIDENCY_FLOOR || i8_toks_ratio < TOKS_FLOOR {
        eprintln!(
            "FAIL: int8 paging must hold >= {INT8_RESIDENCY_FLOOR}x dense \
             resident requests at fixed memory without dropping below \
             {TOKS_FLOOR}x dense tok/s (got {i8_residency_ratio:.2}x, \
             {i8_toks_ratio:.2}) — see table above"
        );
        std::process::exit(1);
    }
    Ok(())
}
