//! Native compute-layer throughput: naive vs cache-blocked matmul
//! GFLOP/s, SIMD-vs-scalar on the fused ConSmax tail, and prefill /
//! decode thread-scaling — the measurable claims of the
//! parallel-compute and SIMD-seam PRs (EXPERIMENTS.md §Forward &
//! prefill throughput).
//!
//! Run: `cargo bench --bench forward_bench` (no artifacts, no Python).
//! Emits machine-readable results to `BENCH_forward.json` (raw timings
//! to `BENCH_forward_raw.jsonl`) and exits non-zero if any floor
//! fails, all measured single-threaded so the floors grade the
//! kernels, not the pool:
//!
//! * tiled matmul must clear **2× naive GFLOP/s at d ≥ 256**, and on
//!   AVX2 hosts an absolute **2.5 GFLOP/s** as well (the raised
//!   SIMD-era floor);
//! * the SIMD fused score→C·exp→PV tail must beat the `--simd off`
//!   scalar/libm tail by **1.5×**.
//!
//! CI smoke-runs this so the artifacts and the speedup claims cannot
//! rot. Thread-scaling numbers are reported, not gated: they depend on
//! the host's core count (recorded in the JSON).
//!
//! The bench also asserts the determinism contract inline: prefill and
//! decode logits at 4 threads must be bit-identical to 1 thread, and
//! the SIMD tail must agree with the scalar tail within the seam's
//! documented exp tolerance.

use std::time::Instant;

use consmax::config::ModelConfig;
use consmax::coordinator::ParamStore;
use consmax::runtime::backend::{native, simd, DecodeSession, NativeModel};
use consmax::runtime::parallel;
use consmax::util::bench::{print_table, Bencher};
use consmax::util::json::Json;
use consmax::util::rng::Pcg32;

/// The tiled kernel must beat the naive oracle by this factor at d≥256.
const MIN_TILED_SPEEDUP: f64 = 2.0;
/// Absolute single-thread floor for the tiled kernel at d ≥ 256 on
/// AVX2 hosts (portable/unknown hosts only get the relative floor).
const MIN_TILED_GFLOPS_AVX2: f64 = 2.5;
/// The SIMD fused ConSmax tail must beat the scalar/libm tail by this.
const MIN_TAIL_SPEEDUP: f64 = 1.5;
/// Worker counts for the scaling sweep.
const THREADS: [usize; 3] = [1, 2, 4];
/// Decode steps per timed repetition.
const DECODE_STEPS: usize = 32;
/// Fused-tail workload: keys attended per call and head dimension
/// (small head → the exp stream dominates, which is what the floor
/// grades).
const TAIL_KEYS: usize = 4096;
const TAIL_HD: usize = 32;

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::coarse();
    let mut rng = Pcg32::seeded(0);

    // the bench grades the SIMD seam itself, so pin the mode rather
    // than inherit CONSMAX_SIMD (the scalar leg flips to Off below)
    simd::set_mode(simd::Mode::Auto);
    let simd_level = simd::level();
    println!("simd level: {}\n", simd_level.name());

    // ---- naive vs tiled matmul ---------------------------------------
    let mut matmul_rows = Vec::new();
    let mut matmul_cases = Vec::new();
    let mut floor_ok = true;
    for d in [64usize, 256] {
        let (m, k, n) = (d, d, d);
        let a = rng.normal_vec_f32(m * k, 0.0, 1.0);
        let bmat = rng.normal_vec_f32(k * n, 0.0, 1.0);
        let bt = native::transpose(&bmat, k, n);
        let flops = (2 * m * k * n) as f64;

        parallel::set_threads(1);
        let naive = b
            .bench(&format!("matmul naive {d}x{d}x{d}"), || {
                native::matmul(&a, &bmat, m, k, n)
            })
            .clone();
        let tiled = b
            .bench(&format!("matmul tiled {d}x{d}x{d} (1 thread)"), || {
                native::matmul_bt(&a, &bt, m, k, n)
            })
            .clone();
        parallel::set_threads(0); // default: all cores / CONSMAX_THREADS
        let tiled_mt = b
            .bench(&format!("matmul tiled {d}x{d}x{d} (all cores)"), || {
                native::matmul_bt(&a, &bt, m, k, n)
            })
            .clone();

        // ns per iter -> GFLOP/s is flops/ns
        let naive_gflops = flops / naive.median_ns;
        let tiled_gflops = flops / tiled.median_ns;
        let tiled_mt_gflops = flops / tiled_mt.median_ns;
        let speedup = tiled_gflops / naive_gflops;
        if d >= 256 {
            floor_ok &= speedup >= MIN_TILED_SPEEDUP;
            if simd_level == simd::Level::Avx2 {
                floor_ok &= tiled_gflops >= MIN_TILED_GFLOPS_AVX2;
            }
        }
        matmul_rows.push(vec![
            format!("{d}"),
            format!("{naive_gflops:.2}"),
            format!("{tiled_gflops:.2}"),
            format!("{tiled_mt_gflops:.2}"),
            format!("{speedup:.1}x"),
        ]);
        matmul_cases.push(Json::from_pairs([
            ("d".to_string(), Json::from(d)),
            ("naive_gflops".to_string(), Json::from(naive_gflops)),
            ("tiled_gflops_1t".to_string(), Json::from(tiled_gflops)),
            ("tiled_gflops_mt".to_string(), Json::from(tiled_mt_gflops)),
            ("tiled_vs_naive_1t".to_string(), Json::from(speedup)),
        ]));
    }
    print_table(
        "Matmul kernels (GFLOP/s; floor: tiled >= 2x naive at d>=256)",
        &["d", "naive", "tiled 1t", "tiled mt", "tiled/naive (1t)"],
        &matmul_rows,
    );

    // ---- SIMD vs scalar on the fused ConSmax tail --------------------
    // one decode-shaped attend over TAIL_KEYS cached keys: score →
    // C·exp → PV per key with no materialized prob row. `--simd off`
    // is the scalar/libm reference; the floor holds the polynomial-exp
    // stream's win. Single-threaded: the floor grades the kernel.
    parallel::set_threads(1);
    let tq: Vec<f32> = (0..TAIL_HD).map(|i| 0.3 - 0.02 * i as f32).collect();
    let tk = rng.normal_vec_f32(TAIL_KEYS * TAIL_HD, 0.0, 1.0);
    let tv = rng.normal_vec_f32(TAIL_KEYS * TAIL_HD, 0.0, 1.0);
    let (tscale, tbeta, tgamma) = (1.0 / (TAIL_HD as f32).sqrt(), 1.5f32, 100.0f32);
    let run_tail = || {
        let mut y = vec![0.0f32; TAIL_HD];
        native::attend_consmax(
            &tq, &tk, &tv, TAIL_HD, tscale, tbeta, tgamma, &mut y,
        );
        y
    };

    simd::set_mode(simd::Mode::Off);
    let y_scalar = run_tail();
    let tail_scalar = b
        .bench(&format!("consmax tail {TAIL_KEYS} keys (scalar/libm)"), run_tail)
        .clone();
    simd::set_mode(simd::Mode::Auto);
    let y_simd = run_tail();
    let tail_simd = b
        .bench(
            &format!("consmax tail {TAIL_KEYS} keys ({})", simd_level.name()),
            run_tail,
        )
        .clone();

    // correctness smoke: both modes agree within the seam's documented
    // exp tolerance (the reductions are bit-identical; only exp differs)
    for (i, (s, f)) in y_scalar.iter().zip(&y_simd).enumerate() {
        let tol = 1e-4 * s.abs().max(f.abs()).max(1.0);
        assert!(
            (s - f).abs() <= tol,
            "tail[{i}]: simd {f} vs scalar {s} beyond exp tolerance"
        );
    }

    let tail_speedup = tail_scalar.median_ns / tail_simd.median_ns;
    let tail_floor_ok = tail_speedup >= MIN_TAIL_SPEEDUP;
    print_table(
        &format!(
            "Fused ConSmax tail, {TAIL_KEYS} keys x hd {TAIL_HD} \
             (floor: simd >= {MIN_TAIL_SPEEDUP}x scalar)"
        ),
        &["leg", "ns/call", "keys/us"],
        &[
            vec![
                "scalar/libm".to_string(),
                format!("{:.0}", tail_scalar.median_ns),
                format!("{:.1}", TAIL_KEYS as f64 / (tail_scalar.median_ns * 1e-3)),
            ],
            vec![
                simd_level.name().to_string(),
                format!("{:.0}", tail_simd.median_ns),
                format!("{:.1}", TAIL_KEYS as f64 / (tail_simd.median_ns * 1e-3)),
            ],
        ],
    );
    println!("fused-tail simd speedup: {tail_speedup:.2}x over scalar");

    // ---- model + workloads -------------------------------------------
    let cfg = ModelConfig::builtin("tiny", "consmax")?;
    let store = ParamStore::init(&cfg, 0)?;
    let model = NativeModel::from_params(&cfg, &store.order, &store.params)?;
    let v = cfg.vocab;
    let batch = 8usize;

    // prefill workload: near-ctx prompts, the serving entry shape
    let prompt_len = cfg.ctx - 16;
    let prefill_rows: Vec<Vec<i32>> = (0..batch)
        .map(|r| {
            (0..prompt_len)
                .map(|i| ((i * 31 + r * 7 + 1) % 256) as i32)
                .collect()
        })
        .collect();
    let mut sess = DecodeSession::new(&cfg, batch);

    // the determinism contract, asserted on the real model
    parallel::set_threads(1);
    let serial_logits = model.prefill(&mut sess, &prefill_rows)?;
    parallel::set_threads(4);
    let threaded_logits = model.prefill(&mut sess, &prefill_rows)?;
    assert_eq!(
        serial_logits, threaded_logits,
        "threaded prefill is not bit-identical to single-thread"
    );

    let mut prefill_rows_out = Vec::new();
    let mut prefill_cases = Vec::new();
    let mut prefill_tok_s = Vec::new();
    for &nt in &THREADS {
        parallel::set_threads(nt);
        let stats = b
            .bench(&format!("prefill b{batch} x {prompt_len} toks ({nt} thr)"), || {
                model.prefill(&mut sess, &prefill_rows).unwrap()
            })
            .clone();
        let tok_s = stats.throughput((batch * prompt_len) as f64);
        prefill_tok_s.push(tok_s);
        prefill_rows_out.push(vec![format!("{nt}"), format!("{tok_s:.0}")]);
        prefill_cases.push(Json::from_pairs([
            ("threads".to_string(), Json::from(nt)),
            ("tok_s".to_string(), Json::from(tok_s)),
        ]));
    }
    let prefill_scaling = prefill_tok_s.last().unwrap() / prefill_tok_s[0];
    print_table(
        &format!("Prefill thread scaling (b{batch}, {prompt_len}-token prompts)"),
        &["threads", "tok/s"],
        &prefill_rows_out,
    );
    println!("prefill scaling at 4 threads: {prefill_scaling:.2}x over 1 thread");

    // ---- decode scaling ----------------------------------------------
    // short prompts + a 32-step greedy decode loop per repetition; only
    // the decode portion is timed (prefill excluded)
    let short_rows: Vec<Vec<i32>> =
        (0..batch).map(|r| vec![(r as i32) + 5; 16]).collect();

    // bit-identity across thread counts on the decode path too
    let decode_trace = |threads: usize,
                        sess: &mut DecodeSession|
     -> anyhow::Result<Vec<f32>> {
        parallel::set_threads(threads);
        let mut trace = model.prefill(sess, &short_rows)?;
        let mut last: Vec<i32> =
            (0..batch).map(|r| argmax(&trace[r * v..(r + 1) * v]) as i32).collect();
        for _ in 0..8 {
            let logits = model.decode_step(sess, &last)?;
            for r in 0..batch {
                last[r] = argmax(&logits[r * v..(r + 1) * v]) as i32;
            }
            trace.extend_from_slice(&logits);
        }
        Ok(trace)
    };
    let t1 = decode_trace(1, &mut sess)?;
    let t4 = decode_trace(4, &mut sess)?;
    assert_eq!(t1, t4, "threaded decode is not bit-identical to single-thread");

    let mut decode_rows_out = Vec::new();
    let mut decode_cases = Vec::new();
    let mut decode_tok_s = Vec::new();
    for &nt in &THREADS {
        parallel::set_threads(nt);
        let mut timed_ns = 0.0f64;
        let mut tokens = 0usize;
        for _ in 0..5 {
            model.prefill(&mut sess, &short_rows)?;
            let mut last = vec![7i32; batch];
            let t0 = Instant::now();
            for _ in 0..DECODE_STEPS {
                let logits = model.decode_step(&mut sess, &last)?;
                for r in 0..batch {
                    last[r] = argmax(&logits[r * v..(r + 1) * v]) as i32;
                }
            }
            timed_ns += t0.elapsed().as_nanos() as f64;
            tokens += batch * DECODE_STEPS;
        }
        let tok_s = tokens as f64 / (timed_ns * 1e-9);
        decode_tok_s.push(tok_s);
        decode_rows_out.push(vec![format!("{nt}"), format!("{tok_s:.0}")]);
        decode_cases.push(Json::from_pairs([
            ("threads".to_string(), Json::from(nt)),
            ("tok_s".to_string(), Json::from(tok_s)),
        ]));
    }
    parallel::set_threads(0);
    let decode_scaling = decode_tok_s.last().unwrap() / decode_tok_s[0];
    print_table(
        &format!("KV-decode thread scaling (b{batch}, {DECODE_STEPS} steps)"),
        &["threads", "tok/s"],
        &decode_rows_out,
    );
    println!("decode scaling at 4 threads: {decode_scaling:.2}x over 1 thread");

    let host_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let doc = Json::from_pairs([
        ("bench".to_string(), Json::from("forward")),
        ("config".to_string(), Json::from(cfg.key.as_str())),
        ("ctx".to_string(), Json::from(cfg.ctx)),
        ("batch".to_string(), Json::from(batch)),
        ("host_threads".to_string(), Json::from(host_threads)),
        ("simd_level".to_string(), Json::from(simd_level.name())),
        (
            "min_tiled_speedup_required".to_string(),
            Json::from(MIN_TILED_SPEEDUP),
        ),
        (
            "min_tiled_gflops_avx2".to_string(),
            Json::from(MIN_TILED_GFLOPS_AVX2),
        ),
        ("tiled_floor_ok".to_string(), Json::from(floor_ok)),
        (
            "tail".to_string(),
            Json::from_pairs([
                ("keys".to_string(), Json::from(TAIL_KEYS)),
                ("head_dim".to_string(), Json::from(TAIL_HD)),
                ("scalar_ns".to_string(), Json::from(tail_scalar.median_ns)),
                ("simd_ns".to_string(), Json::from(tail_simd.median_ns)),
                ("speedup".to_string(), Json::from(tail_speedup)),
            ]),
        ),
        (
            "min_tail_speedup_required".to_string(),
            Json::from(MIN_TAIL_SPEEDUP),
        ),
        ("tail_floor_ok".to_string(), Json::from(tail_floor_ok)),
        ("matmul".to_string(), Json::Arr(matmul_cases)),
        ("prefill".to_string(), Json::Arr(prefill_cases)),
        ("prefill_scaling_4t".to_string(), Json::from(prefill_scaling)),
        ("decode".to_string(), Json::Arr(decode_cases)),
        ("decode_scaling_4t".to_string(), Json::from(decode_scaling)),
        ("threaded_bit_identical".to_string(), Json::from(true)),
    ]);
    std::fs::write("BENCH_forward.json", doc.to_string())?;
    b.save_json(std::path::Path::new("BENCH_forward_raw.jsonl"))?;
    println!("\nwrote BENCH_forward.json (+ BENCH_forward_raw.jsonl)");

    if prefill_scaling < 1.5 {
        println!(
            "note: prefill scaling {prefill_scaling:.2}x < 1.5x at 4 threads \
             (host has {host_threads} cores; not gated)"
        );
    }
    let mut failed = false;
    if !floor_ok {
        eprintln!(
            "FAIL: tiled matmul did not clear the {MIN_TILED_SPEEDUP}x \
             floor over naive at d >= 256 (or, on AVX2, the absolute \
             {MIN_TILED_GFLOPS_AVX2} GFLOP/s floor; see table above)"
        );
        failed = true;
    }
    if !tail_floor_ok {
        eprintln!(
            "FAIL: SIMD fused ConSmax tail only {tail_speedup:.2}x over \
             scalar/libm (floor {MIN_TAIL_SPEEDUP}x; see table above)"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    Ok(())
}
