//! Scheduler shoot-out: continuous batching vs the static reference
//! batcher, under a Poisson arrival mix of short and long generation
//! budgets — the head-of-line-blocking workload of DESIGN.md §Serving
//! seam (EXPERIMENTS.md §Continuous vs static serving).
//!
//! Run: `cargo bench --bench serve_bench` (native, no artifacts).
//! Emits machine-readable results to `BENCH_serve.json` in the working
//! directory and exits non-zero unless the continuous scheduler clears
//! **≥ 1.5× static token throughput with a lower p99 TTFT** on the
//! same arrival schedule — CI smoke-runs this so the artifact and the
//! scheduling claim cannot rot.
//!
//! Both runs serve the identical schedule greedily, so they emit the
//! identical tokens (the equivalence suite pins this per request);
//! only the scheduling differs. The pool is capped at [`SLOTS`] rows
//! so the comparison grades the scheduler, not the pool size.
//!
//! A third **overload** leg (DESIGN.md §Serving-robustness seam) offers
//! requests open-loop at [`OVERLOAD_FACTOR`]× the sustainable rate just
//! measured, with bounded admission ([`OVERLOAD_QUEUE_CAP`] queued).
//! The gate: the server must *shed* rather than queue unboundedly
//! (`shed > 0`), every request must reach exactly one terminal state
//! (`completed + shed == submitted` — zero silent drops), and p99 TTFT
//! of the admitted requests must stay under
//! [`OVERLOAD_TTFT_P99_LIMIT_MS`], the documented bound.

use std::time::{Duration, Instant};

use consmax::config::ModelConfig;
use consmax::coordinator::{
    Admission, GenRequest, Generator, ParamStore, Server,
};
use consmax::metrics::LatencyRecorder;
use consmax::util::bench::print_table;
use consmax::util::json::Json;
use consmax::util::rng::Pcg32;

/// Requests per run (every 8th is long, the rest short).
const N_REQUESTS: usize = 48;
/// Token budget of the short requests.
const SHORT_NEW: usize = 2;
/// Token budget of the long requests (ctx 64 ⇒ prompts clamp to 8).
const LONG_NEW: usize = 56;
/// Serving slot-pool cap for both schedulers.
const SLOTS: usize = 4;
/// Offered load: mean inter-arrival seconds (saturating).
const MEAN_ARRIVAL_S: f64 = 1e-3;
/// The throughput floor continuous must clear (acceptance criterion).
const MIN_SPEEDUP: f64 = 1.5;
/// Measured runs per scheduler; the best-throughput run is reported.
const RUNS: usize = 2;
/// Overload leg: offered request rate as a multiple of the sustainable
/// rate measured on the continuous run.
const OVERLOAD_FACTOR: f64 = 2.0;
/// Bounded admission during overload: shed past this queue depth.
const OVERLOAD_QUEUE_CAP: usize = 8;
/// Documented bound: p99 TTFT of *admitted* requests under overload.
/// Bounded admission keeps the queue short, so time-to-first-token
/// stays near the no-overload p99 instead of growing with backlog.
const OVERLOAD_TTFT_P99_LIMIT_MS: f64 = 1500.0;

struct RunStats {
    wall_s: f64,
    tokens: u64,
    tok_s: f64,
    lat_p50_ms: f64,
    lat_p99_ms: f64,
    ttft_p50_ms: f64,
    ttft_p99_ms: f64,
    /// Median completion latency of the short/long requests separately:
    /// per-request accounting makes these *differ* under one roof.
    short_lat_p50_ms: f64,
    long_lat_p50_ms: f64,
}

fn schedule(seed: u64) -> Vec<(f64, GenRequest)> {
    let mut rng = Pcg32::seeded(seed);
    let prompts = [
        "The constant softmax replaces the row reduction ",
        "Attention lets every token attend ",
        "A small lookup table stores the exponent ",
        "Long contexts make the normalizer the bottleneck ",
    ];
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(N_REQUESTS);
    for id in 0..N_REQUESTS as u64 {
        t += rng.exponential(1.0 / MEAN_ARRIVAL_S);
        out.push((t, GenRequest {
            id,
            prompt: prompts[rng.below(prompts.len() as u64) as usize].into(),
            max_new_tokens: if id % 8 == 7 { LONG_NEW } else { SHORT_NEW },
            temperature: 0.0, // greedy: both schedulers emit identical tokens
            stop: None,
            deadline_ms: None,
        }));
    }
    out
}

fn run_schedule(
    cfg: &ModelConfig,
    store: &ParamStore,
    sched: &[(f64, GenRequest)],
    continuous: bool,
) -> anyhow::Result<RunStats> {
    let generator = Generator::native(cfg, store, 7)?;
    let mut server = Server::new(generator);
    server.set_max_batch(SLOTS)?;

    let mut responses = Vec::with_capacity(sched.len());
    let t0 = Instant::now();
    let mut next = 0;
    while responses.len() < sched.len() {
        let now = t0.elapsed().as_secs_f64();
        while next < sched.len() && sched[next].0 <= now {
            server.submit(sched[next].1.clone());
            next += 1;
        }
        let idle = server.pending() == 0
            && (!continuous || server.in_flight() == 0);
        if idle {
            std::thread::sleep(Duration::from_micros(100));
            continue;
        }
        let done = if continuous { server.step()? } else { server.run_once()? };
        responses.extend(done);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    // short/long medians through the same nearest-rank percentile as
    // every other number in the table
    let mut short = LatencyRecorder::default();
    let mut long = LatencyRecorder::default();
    for r in &responses {
        if r.new_tokens <= SHORT_NEW {
            short.record_us(r.latency_ms * 1e3);
        } else {
            long.record_us(r.latency_ms * 1e3);
        }
    }
    Ok(RunStats {
        wall_s,
        tokens: server.tokens_out,
        tok_s: server.tokens_out as f64 / wall_s,
        lat_p50_ms: server.latencies.percentile(50.0).unwrap_or(0.0) / 1e3,
        lat_p99_ms: server.latencies.percentile(99.0).unwrap_or(0.0) / 1e3,
        ttft_p50_ms: server.ttft.percentile(50.0).unwrap_or(0.0) / 1e3,
        ttft_p99_ms: server.ttft.percentile(99.0).unwrap_or(0.0) / 1e3,
        short_lat_p50_ms: short.percentile(50.0).unwrap_or(0.0) / 1e3,
        long_lat_p50_ms: long.percentile(50.0).unwrap_or(0.0) / 1e3,
    })
}

struct OverloadStats {
    offered_qps: f64,
    wall_s: f64,
    submitted: u64,
    admitted: u64,
    shed: u64,
    completed: u64,
    ttft_p99_ms: f64,
}

/// Offer the same request mix open-loop at `offered_qps` against a
/// bounded queue; the server decides per arrival: admit or shed.
fn run_overload(
    cfg: &ModelConfig,
    store: &ParamStore,
    sched: &[(f64, GenRequest)],
    offered_qps: f64,
) -> anyhow::Result<OverloadStats> {
    let generator = Generator::native(cfg, store, 7)?;
    let mut server = Server::new(generator);
    server.set_max_batch(SLOTS)?;
    server.set_admission_limits(Some(OVERLOAD_QUEUE_CAP), None);

    let gap_s = 1.0 / offered_qps;
    let mut admitted = 0u64;
    let t0 = Instant::now();
    let mut next = 0usize;
    loop {
        let now = t0.elapsed().as_secs_f64();
        while next < sched.len() && next as f64 * gap_s <= now {
            match server.try_submit(sched[next].1.clone()) {
                Admission::Admitted => admitted += 1,
                Admission::Shed { .. } => {} // counted in server.shed
            }
            next += 1;
        }
        let idle = server.pending() == 0 && server.in_flight() == 0;
        if idle && next >= sched.len() {
            break; // every admitted request has completed
        }
        if idle {
            std::thread::sleep(Duration::from_micros(100));
            continue;
        }
        server.step()?;
    }
    Ok(OverloadStats {
        offered_qps,
        wall_s: t0.elapsed().as_secs_f64(),
        submitted: server.submitted,
        admitted,
        shed: server.shed,
        completed: server.completed,
        ttft_p99_ms: server.ttft.percentile(99.0).unwrap_or(0.0) / 1e3,
    })
}

fn best(mut runs: Vec<RunStats>) -> RunStats {
    runs.sort_by(|a, b| a.tok_s.partial_cmp(&b.tok_s).unwrap());
    runs.pop().unwrap()
}

fn stats_json(s: &RunStats) -> Json {
    Json::from_pairs([
        ("wall_s".to_string(), Json::from(s.wall_s)),
        ("tokens".to_string(), Json::from(s.tokens as f64)),
        ("tok_s".to_string(), Json::from(s.tok_s)),
        ("lat_p50_ms".to_string(), Json::from(s.lat_p50_ms)),
        ("lat_p99_ms".to_string(), Json::from(s.lat_p99_ms)),
        ("ttft_p50_ms".to_string(), Json::from(s.ttft_p50_ms)),
        ("ttft_p99_ms".to_string(), Json::from(s.ttft_p99_ms)),
        ("short_lat_p50_ms".to_string(), Json::from(s.short_lat_p50_ms)),
        ("long_lat_p50_ms".to_string(), Json::from(s.long_lat_p50_ms)),
    ])
}

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::builtin("tiny", "consmax")?;
    let store = ParamStore::init(&cfg, 0)?;
    let sched = schedule(11);

    // interleave static/continuous runs so machine noise hits both
    let mut stat_runs = Vec::new();
    let mut cont_runs = Vec::new();
    for _ in 0..RUNS {
        stat_runs.push(run_schedule(&cfg, &store, &sched, false)?);
        cont_runs.push(run_schedule(&cfg, &store, &sched, true)?);
    }
    let stat = best(stat_runs);
    let cont = best(cont_runs);
    let speedup = cont.tok_s / stat.tok_s;
    let ttft_ok = cont.ttft_p99_ms < stat.ttft_p99_ms;

    let row = |name: &str, s: &RunStats| {
        vec![
            name.to_string(),
            format!("{:.0}", s.tok_s),
            format!("{:.0}", s.lat_p50_ms),
            format!("{:.0}", s.lat_p99_ms),
            format!("{:.0}", s.ttft_p50_ms),
            format!("{:.0}", s.ttft_p99_ms),
            format!("{:.0}/{:.0}", s.short_lat_p50_ms, s.long_lat_p50_ms),
        ]
    };
    print_table(
        &format!(
            "Serving schedulers, {} ({} reqs, {}:{} short/long budget mix, \
             {} slots, Poisson arrivals)",
            cfg.key, N_REQUESTS, SHORT_NEW, LONG_NEW, SLOTS
        ),
        &["scheduler", "tok/s", "lat p50 ms", "lat p99 ms", "ttft p50 ms",
          "ttft p99 ms", "short/long p50 ms"],
        &[row("static", &stat), row("continuous", &cont)],
    );
    println!(
        "\ncontinuous/static token throughput: {speedup:.2}x \
         (floor {MIN_SPEEDUP}x); p99 TTFT {} ms vs {} ms",
        cont.ttft_p99_ms.round(),
        stat.ttft_p99_ms.round()
    );

    // overload leg: 2x the sustainable request rate just measured,
    // against a bounded queue — shed, don't queue unboundedly
    let sustainable_qps = N_REQUESTS as f64 / cont.wall_s;
    let over =
        run_overload(&cfg, &store, &sched, OVERLOAD_FACTOR * sustainable_qps)?;
    let no_silent_drops = over.completed + over.shed == over.submitted
        && over.admitted == over.completed;
    let overload_ok = over.shed > 0
        && no_silent_drops
        && over.ttft_p99_ms <= OVERLOAD_TTFT_P99_LIMIT_MS;
    println!(
        "overload @ {:.0} req/s ({OVERLOAD_FACTOR}x sustainable, queue cap \
         {OVERLOAD_QUEUE_CAP}): {} offered = {} completed + {} shed; \
         admitted p99 TTFT {:.0} ms (limit {OVERLOAD_TTFT_P99_LIMIT_MS} ms)",
        over.offered_qps,
        over.submitted,
        over.completed,
        over.shed,
        over.ttft_p99_ms,
    );

    let doc = Json::from_pairs([
        ("bench".to_string(), Json::from("serve")),
        ("config".to_string(), Json::from(cfg.key.as_str())),
        ("normalizer".to_string(), Json::from(cfg.normalizer.as_str())),
        ("requests".to_string(), Json::from(N_REQUESTS)),
        ("short_new".to_string(), Json::from(SHORT_NEW)),
        ("long_new".to_string(), Json::from(LONG_NEW)),
        ("slots".to_string(), Json::from(SLOTS)),
        (
            "threads".to_string(),
            Json::from(consmax::runtime::parallel::current_threads()),
        ),
        ("static".to_string(), stats_json(&stat)),
        ("continuous".to_string(), stats_json(&cont)),
        ("speedup".to_string(), Json::from(speedup)),
        ("min_speedup_required".to_string(), Json::from(MIN_SPEEDUP)),
        ("ttft_p99_lower".to_string(), Json::from(ttft_ok)),
        (
            "overload".to_string(),
            Json::from_pairs([
                ("factor".to_string(), Json::from(OVERLOAD_FACTOR)),
                ("queue_cap".to_string(), Json::from(OVERLOAD_QUEUE_CAP)),
                ("offered_qps".to_string(), Json::from(over.offered_qps)),
                ("wall_s".to_string(), Json::from(over.wall_s)),
                (
                    "submitted".to_string(),
                    Json::from(over.submitted as f64),
                ),
                ("admitted".to_string(), Json::from(over.admitted as f64)),
                ("shed".to_string(), Json::from(over.shed as f64)),
                (
                    "completed".to_string(),
                    Json::from(over.completed as f64),
                ),
                ("ttft_p99_ms".to_string(), Json::from(over.ttft_p99_ms)),
                (
                    "ttft_p99_limit_ms".to_string(),
                    Json::from(OVERLOAD_TTFT_P99_LIMIT_MS),
                ),
                (
                    "no_silent_drops".to_string(),
                    Json::from(no_silent_drops),
                ),
            ]),
        ),
        ("overload_ok".to_string(), Json::from(overload_ok)),
    ]);
    std::fs::write("BENCH_serve.json", doc.to_string())?;
    println!("wrote BENCH_serve.json");

    if speedup < MIN_SPEEDUP || !ttft_ok {
        eprintln!(
            "FAIL: continuous batching must clear {MIN_SPEEDUP}x static \
             token throughput with lower p99 TTFT (got {speedup:.2}x, \
             ttft_p99_lower={ttft_ok}) — see table above"
        );
        std::process::exit(1);
    }
    if !overload_ok {
        eprintln!(
            "FAIL: under {OVERLOAD_FACTOR}x overload the server must shed \
             (shed={}, want >0), account for every request \
             (completed {} + shed {} == submitted {}, admitted {} == \
             completed), and keep admitted p99 TTFT <= \
             {OVERLOAD_TTFT_P99_LIMIT_MS} ms (got {:.0} ms)",
            over.shed,
            over.completed,
            over.shed,
            over.submitted,
            over.admitted,
            over.ttft_p99_ms,
        );
        std::process::exit(1);
    }
    Ok(())
}
