//! Scheduler shoot-out: continuous batching vs the static reference
//! batcher, under a Poisson arrival mix of short and long generation
//! budgets — the head-of-line-blocking workload of DESIGN.md §Serving
//! seam (EXPERIMENTS.md §Continuous vs static serving).
//!
//! Run: `cargo bench --bench serve_bench` (native, no artifacts).
//! Emits machine-readable results to `BENCH_serve.json` in the working
//! directory and exits non-zero unless the continuous scheduler clears
//! **≥ 1.5× static token throughput with a lower p99 TTFT** on the
//! same arrival schedule — CI smoke-runs this so the artifact and the
//! scheduling claim cannot rot.
//!
//! Both runs serve the identical schedule greedily, so they emit the
//! identical tokens (the equivalence suite pins this per request);
//! only the scheduling differs. The pool is capped at [`SLOTS`] rows
//! so the comparison grades the scheduler, not the pool size.
//!
//! A third **overload** leg (DESIGN.md §Serving-robustness seam) offers
//! requests open-loop at [`OVERLOAD_FACTOR`]× the sustainable rate just
//! measured, with bounded admission ([`OVERLOAD_QUEUE_CAP`] queued).
//! The gate: the server must *shed* rather than queue unboundedly
//! (`shed > 0`), every request must reach exactly one terminal state
//! (`completed + shed == submitted` — zero silent drops), and p99 TTFT
//! of the admitted requests must stay under
//! [`OVERLOAD_TTFT_P99_LIMIT_MS`], the documented bound.
//!
//! Two further legs grade the latency features of DESIGN.md
//! §Speculation-and-chunking seam on the `paper` config (big enough
//! that a long prefill and a decode step have real wall-clock cost):
//!
//! * **Chunked prefill** — a Poisson stream of short requests with one
//!   giant-prompt request in the middle. Monolithic prefill stalls a
//!   whole tick on that prompt and every short arriving behind it eats
//!   the stall; `--prefill-chunk` spreads the ingestion across ticks.
//!   Gate: p99 TTFT with chunking is **lower than monolithic** and
//!   under [`CHUNK_TTFT_P99_LIMIT_MS`].
//! * **Accept-heavy speculation** — target and tiny draft share a
//!   rigged final-LN bias (`lnf_b += λ·wte[c]`, both stores), so every
//!   greedy argmax is token `c` and every draft proposal verifies.
//!   This isolates the mechanical ceiling of the speculation loop: the
//!   target scores K+1 positions per `extend_rows` call, streaming its
//!   weights once instead of K+1 times. Gate: **≥ [`MIN_SPEC_SPEEDUP`]×
//!   decode tok/s** over the spec-off run at **≥ [`MIN_ACCEPTANCE`]
//!   acceptance**, with bit-identical tokens.

use std::time::{Duration, Instant};

use consmax::config::{ModelConfig, QuantMode};
use consmax::coordinator::{
    Admission, GenRequest, Generator, ParamStore, Server, SpecConfig,
};
use consmax::metrics::LatencyRecorder;
use consmax::runtime::backend::NativeModel;
use consmax::runtime::HostTensor;
use consmax::util::bench::print_table;
use consmax::util::json::Json;
use consmax::util::rng::Pcg32;

/// Requests per run (every 8th is long, the rest short).
const N_REQUESTS: usize = 48;
/// Token budget of the short requests.
const SHORT_NEW: usize = 2;
/// Token budget of the long requests (ctx 64 ⇒ prompts clamp to 8).
const LONG_NEW: usize = 56;
/// Serving slot-pool cap for both schedulers.
const SLOTS: usize = 4;
/// Offered load: mean inter-arrival seconds (saturating).
const MEAN_ARRIVAL_S: f64 = 1e-3;
/// The throughput floor continuous must clear (acceptance criterion).
const MIN_SPEEDUP: f64 = 1.5;
/// Measured runs per scheduler; the best-throughput run is reported.
const RUNS: usize = 2;
/// Overload leg: offered request rate as a multiple of the sustainable
/// rate measured on the continuous run.
const OVERLOAD_FACTOR: f64 = 2.0;
/// Bounded admission during overload: shed past this queue depth.
const OVERLOAD_QUEUE_CAP: usize = 8;
/// Documented bound: p99 TTFT of *admitted* requests under overload.
/// Bounded admission keeps the queue short, so time-to-first-token
/// stays near the no-overload p99 instead of growing with backlog.
const OVERLOAD_TTFT_P99_LIMIT_MS: f64 = 1500.0;

// ——— chunked-prefill leg (paper config) ———
/// Requests in the chunking leg: 99 shorts + exactly one giant prompt,
/// so nearest-rank p99 (rank 99 of 100) grades the worst *short* — the
/// giant request pays for its own ingestion under either policy and is
/// excluded, the shorts stuck behind it are not.
const CHUNK_REQS: usize = 100;
/// Arrival index of the giant-prompt request.
const CHUNK_LONG_AT: u64 = 33;
/// Prompt bytes of the giant request (paper ctx is 256).
const CHUNK_LONG_PROMPT: usize = 240;
/// `--prefill-chunk` size for the chunked run.
const CHUNK_SIZE: usize = 8;
/// Token budget of the short requests in the chunking leg.
const CHUNK_SHORT_NEW: usize = 4;
/// Absolute documented bound on chunked p99 TTFT.
const CHUNK_TTFT_P99_LIMIT_MS: f64 = 1500.0;

// ——— accept-heavy speculative leg (paper target, tiny draft) ———
/// Requests in the speculation leg (decode-heavy: short prompts).
const SPEC_REQS: usize = 12;
/// Token budget per request in the speculation leg.
const SPEC_NEW: usize = 48;
/// Draft proposals per verification step.
const SPEC_DRAFT_K: usize = 3;
/// Decode-throughput floor the spec run must clear over spec-off.
const MIN_SPEC_SPEEDUP: f64 = 1.5;
/// Acceptance-rate floor for the rigged accept-heavy workload.
const MIN_ACCEPTANCE: f64 = 0.9;
/// The token both rigged models always argmax ('A').
const RIG_TOKEN: usize = 65;
/// Rig strength: `lnf_b += RIG_LAMBDA * wte[RIG_TOKEN]`.
const RIG_LAMBDA: f32 = 1000.0;

struct RunStats {
    wall_s: f64,
    tokens: u64,
    tok_s: f64,
    lat_p50_ms: f64,
    lat_p99_ms: f64,
    ttft_p50_ms: f64,
    ttft_p99_ms: f64,
    /// Median completion latency of the short/long requests separately:
    /// per-request accounting makes these *differ* under one roof.
    short_lat_p50_ms: f64,
    long_lat_p50_ms: f64,
}

fn schedule(seed: u64) -> Vec<(f64, GenRequest)> {
    let mut rng = Pcg32::seeded(seed);
    let prompts = [
        "The constant softmax replaces the row reduction ",
        "Attention lets every token attend ",
        "A small lookup table stores the exponent ",
        "Long contexts make the normalizer the bottleneck ",
    ];
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(N_REQUESTS);
    for id in 0..N_REQUESTS as u64 {
        t += rng.exponential(1.0 / MEAN_ARRIVAL_S);
        out.push((t, GenRequest {
            id,
            prompt: prompts[rng.below(prompts.len() as u64) as usize].into(),
            max_new_tokens: if id % 8 == 7 { LONG_NEW } else { SHORT_NEW },
            temperature: 0.0, // greedy: both schedulers emit identical tokens
            stop: None,
            deadline_ms: None,
        }));
    }
    out
}

fn run_schedule(
    cfg: &ModelConfig,
    store: &ParamStore,
    sched: &[(f64, GenRequest)],
    continuous: bool,
) -> anyhow::Result<RunStats> {
    let generator = Generator::native(cfg, store, 7)?;
    let mut server = Server::new(generator);
    server.set_max_batch(SLOTS)?;

    let mut responses = Vec::with_capacity(sched.len());
    let t0 = Instant::now();
    let mut next = 0;
    while responses.len() < sched.len() {
        let now = t0.elapsed().as_secs_f64();
        while next < sched.len() && sched[next].0 <= now {
            server.submit(sched[next].1.clone());
            next += 1;
        }
        let idle = server.pending() == 0
            && (!continuous || server.in_flight() == 0);
        if idle {
            std::thread::sleep(Duration::from_micros(100));
            continue;
        }
        let done = if continuous { server.step()? } else { server.run_once()? };
        responses.extend(done);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    // short/long medians through the same nearest-rank percentile as
    // every other number in the table
    let mut short = LatencyRecorder::default();
    let mut long = LatencyRecorder::default();
    for r in &responses {
        if r.new_tokens <= SHORT_NEW {
            short.record_us(r.latency_ms * 1e3);
        } else {
            long.record_us(r.latency_ms * 1e3);
        }
    }
    Ok(RunStats {
        wall_s,
        tokens: server.tokens_out,
        tok_s: server.tokens_out as f64 / wall_s,
        lat_p50_ms: server.latencies.percentile(50.0).unwrap_or(0.0) / 1e3,
        lat_p99_ms: server.latencies.percentile(99.0).unwrap_or(0.0) / 1e3,
        ttft_p50_ms: server.ttft.percentile(50.0).unwrap_or(0.0) / 1e3,
        ttft_p99_ms: server.ttft.percentile(99.0).unwrap_or(0.0) / 1e3,
        short_lat_p50_ms: short.percentile(50.0).unwrap_or(0.0) / 1e3,
        long_lat_p50_ms: long.percentile(50.0).unwrap_or(0.0) / 1e3,
    })
}

struct OverloadStats {
    offered_qps: f64,
    wall_s: f64,
    submitted: u64,
    admitted: u64,
    shed: u64,
    completed: u64,
    ttft_p99_ms: f64,
}

/// Offer the same request mix open-loop at `offered_qps` against a
/// bounded queue; the server decides per arrival: admit or shed.
fn run_overload(
    cfg: &ModelConfig,
    store: &ParamStore,
    sched: &[(f64, GenRequest)],
    offered_qps: f64,
) -> anyhow::Result<OverloadStats> {
    let generator = Generator::native(cfg, store, 7)?;
    let mut server = Server::new(generator);
    server.set_max_batch(SLOTS)?;
    server.set_admission_limits(Some(OVERLOAD_QUEUE_CAP), None);

    let gap_s = 1.0 / offered_qps;
    let mut admitted = 0u64;
    let t0 = Instant::now();
    let mut next = 0usize;
    loop {
        let now = t0.elapsed().as_secs_f64();
        while next < sched.len() && next as f64 * gap_s <= now {
            match server.try_submit(sched[next].1.clone()) {
                Admission::Admitted => admitted += 1,
                Admission::Shed { .. } => {} // counted in server.shed
            }
            next += 1;
        }
        let idle = server.pending() == 0 && server.in_flight() == 0;
        if idle && next >= sched.len() {
            break; // every admitted request has completed
        }
        if idle {
            std::thread::sleep(Duration::from_micros(100));
            continue;
        }
        server.step()?;
    }
    Ok(OverloadStats {
        offered_qps,
        wall_s: t0.elapsed().as_secs_f64(),
        submitted: server.submitted,
        admitted,
        shed: server.shed,
        completed: server.completed,
        ttft_p99_ms: server.ttft.percentile(99.0).unwrap_or(0.0) / 1e3,
    })
}

/// Tilt a store so greedy argmax is always [`RIG_TOKEN`]: the LM head
/// is the tied `wte`, so adding `λ·wte[c]` to the final-LN bias puts
/// `λ·⟨wte[c], wte[j]⟩` on every logit — the self inner product wins by
/// ~√d standard deviations at init scale. Applied to target AND draft,
/// every draft proposal is the target's own argmax.
fn rig_always_argmax(store: &mut ParamStore, c: usize, lambda: f32) {
    let wte_i = store.order.iter().position(|n| n == "wte").unwrap();
    let b_i = store.order.iter().position(|n| n == "lnf_b").unwrap();
    let wte = store.params[wte_i].as_f32().unwrap();
    let d = store.params[wte_i].shape[1];
    let mut b = store.params[b_i].as_f32().unwrap();
    for (bv, &wv) in b.iter_mut().zip(&wte[c * d..(c + 1) * d]) {
        *bv += lambda * wv;
    }
    let shape = store.params[b_i].shape.clone();
    store.params[b_i] = HostTensor::from_f32(&b, &shape);
}

struct FeatureRun {
    wall_s: f64,
    tok_s: f64,
    ttft_p99_ms: f64,
    proposed: u64,
    accepted: u64,
    /// Per-request greedy token streams, sorted by id (bit-identity
    /// check between feature-on and feature-off runs).
    tokens: Vec<Vec<i32>>,
}

/// One continuous run with the latency features dialed in: `chunk`
/// turns on chunked prefill, `spec` pairs the target with a draft
/// built from `(draft_k, draft_cfg, draft_store)`.
fn run_feature(
    cfg: &ModelConfig,
    store: &ParamStore,
    sched: &[(f64, GenRequest)],
    chunk: Option<usize>,
    spec: Option<(usize, &ModelConfig, &ParamStore)>,
) -> anyhow::Result<FeatureRun> {
    let generator = Generator::native(cfg, store, 7)?;
    let mut server = Server::new(generator);
    server.set_max_batch(SLOTS)?;
    server.set_prefill_chunk(chunk)?;
    if let Some((k, dcfg, dstore)) = spec {
        let draft = NativeModel::from_params_quant(
            dcfg,
            &dstore.order,
            &dstore.params,
            QuantMode::Off,
        )?;
        server.set_spec(Some((SpecConfig { draft_k: k }, draft)))?;
    }
    let mut responses = Vec::with_capacity(sched.len());
    let t0 = Instant::now();
    let mut next = 0;
    while responses.len() < sched.len() {
        let now = t0.elapsed().as_secs_f64();
        while next < sched.len() && sched[next].0 <= now {
            server.submit(sched[next].1.clone());
            next += 1;
        }
        if server.pending() == 0 && server.in_flight() == 0 {
            std::thread::sleep(Duration::from_micros(100));
            continue;
        }
        responses.extend(server.step()?);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let st = server.stats();
    responses.sort_by_key(|r| r.id);
    Ok(FeatureRun {
        wall_s,
        tok_s: server.tokens_out as f64 / wall_s,
        ttft_p99_ms: server.ttft.percentile(99.0).unwrap_or(0.0) / 1e3,
        proposed: st.spec_proposed,
        accepted: st.spec_accepted,
        tokens: responses.into_iter().map(|r| r.tokens).collect(),
    })
}

/// Short requests for the chunking leg (and its arrival calibration).
fn chunk_short_req(id: u64) -> GenRequest {
    GenRequest {
        id,
        prompt: "short req ".into(),
        max_new_tokens: CHUNK_SHORT_NEW,
        temperature: 0.0,
        stop: None,
        deadline_ms: None,
    }
}

/// Measure one short request's service time on this machine so the
/// Poisson mean keeps the pool busy-but-unsaturated: TTFT must be
/// scheduling-dominated, not backlog-dominated, for the chunking
/// comparison to grade the policy rather than the queue.
fn calibrate_short_s(
    cfg: &ModelConfig,
    store: &ParamStore,
) -> anyhow::Result<f64> {
    let generator = Generator::native(cfg, store, 7)?;
    let mut server = Server::new(generator);
    server.set_max_batch(SLOTS)?;
    let t0 = Instant::now();
    for id in 0..3 {
        server.submit(chunk_short_req(id));
    }
    server.run_continuous()?;
    Ok(t0.elapsed().as_secs_f64() / 3.0)
}

/// Poisson stream of shorts with one giant prompt in the middle.
fn chunk_schedule(mean_gap_s: f64, seed: u64) -> Vec<(f64, GenRequest)> {
    let mut rng = Pcg32::seeded(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(CHUNK_REQS);
    for id in 0..CHUNK_REQS as u64 {
        t += rng.exponential(1.0 / mean_gap_s);
        let req = if id == CHUNK_LONG_AT {
            GenRequest {
                id,
                prompt: "L".repeat(CHUNK_LONG_PROMPT),
                max_new_tokens: 2,
                temperature: 0.0,
                stop: None,
                deadline_ms: None,
            }
        } else {
            chunk_short_req(id)
        };
        out.push((t, req));
    }
    out
}

/// Decode-heavy schedule for the speculation leg: everything arrives
/// up front, short prompts, long greedy budgets.
fn spec_schedule() -> Vec<(f64, GenRequest)> {
    (0..SPEC_REQS as u64)
        .map(|id| {
            (0.0, GenRequest {
                id,
                prompt: "spec bench ".into(),
                max_new_tokens: SPEC_NEW,
                temperature: 0.0,
                stop: None,
                deadline_ms: None,
            })
        })
        .collect()
}

fn best(mut runs: Vec<RunStats>) -> RunStats {
    runs.sort_by(|a, b| a.tok_s.partial_cmp(&b.tok_s).unwrap());
    runs.pop().unwrap()
}

fn stats_json(s: &RunStats) -> Json {
    Json::from_pairs([
        ("wall_s".to_string(), Json::from(s.wall_s)),
        ("tokens".to_string(), Json::from(s.tokens as f64)),
        ("tok_s".to_string(), Json::from(s.tok_s)),
        ("lat_p50_ms".to_string(), Json::from(s.lat_p50_ms)),
        ("lat_p99_ms".to_string(), Json::from(s.lat_p99_ms)),
        ("ttft_p50_ms".to_string(), Json::from(s.ttft_p50_ms)),
        ("ttft_p99_ms".to_string(), Json::from(s.ttft_p99_ms)),
        ("short_lat_p50_ms".to_string(), Json::from(s.short_lat_p50_ms)),
        ("long_lat_p50_ms".to_string(), Json::from(s.long_lat_p50_ms)),
    ])
}

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::builtin("tiny", "consmax")?;
    let store = ParamStore::init(&cfg, 0)?;
    let sched = schedule(11);

    // interleave static/continuous runs so machine noise hits both
    let mut stat_runs = Vec::new();
    let mut cont_runs = Vec::new();
    for _ in 0..RUNS {
        stat_runs.push(run_schedule(&cfg, &store, &sched, false)?);
        cont_runs.push(run_schedule(&cfg, &store, &sched, true)?);
    }
    let stat = best(stat_runs);
    let cont = best(cont_runs);
    let speedup = cont.tok_s / stat.tok_s;
    let ttft_ok = cont.ttft_p99_ms < stat.ttft_p99_ms;

    let row = |name: &str, s: &RunStats| {
        vec![
            name.to_string(),
            format!("{:.0}", s.tok_s),
            format!("{:.0}", s.lat_p50_ms),
            format!("{:.0}", s.lat_p99_ms),
            format!("{:.0}", s.ttft_p50_ms),
            format!("{:.0}", s.ttft_p99_ms),
            format!("{:.0}/{:.0}", s.short_lat_p50_ms, s.long_lat_p50_ms),
        ]
    };
    print_table(
        &format!(
            "Serving schedulers, {} ({} reqs, {}:{} short/long budget mix, \
             {} slots, Poisson arrivals)",
            cfg.key, N_REQUESTS, SHORT_NEW, LONG_NEW, SLOTS
        ),
        &["scheduler", "tok/s", "lat p50 ms", "lat p99 ms", "ttft p50 ms",
          "ttft p99 ms", "short/long p50 ms"],
        &[row("static", &stat), row("continuous", &cont)],
    );
    println!(
        "\ncontinuous/static token throughput: {speedup:.2}x \
         (floor {MIN_SPEEDUP}x); p99 TTFT {} ms vs {} ms",
        cont.ttft_p99_ms.round(),
        stat.ttft_p99_ms.round()
    );

    // overload leg: 2x the sustainable request rate just measured,
    // against a bounded queue — shed, don't queue unboundedly
    let sustainable_qps = N_REQUESTS as f64 / cont.wall_s;
    let over =
        run_overload(&cfg, &store, &sched, OVERLOAD_FACTOR * sustainable_qps)?;
    let no_silent_drops = over.completed + over.shed == over.submitted
        && over.admitted == over.completed;
    let overload_ok = over.shed > 0
        && no_silent_drops
        && over.ttft_p99_ms <= OVERLOAD_TTFT_P99_LIMIT_MS;
    println!(
        "overload @ {:.0} req/s ({OVERLOAD_FACTOR}x sustainable, queue cap \
         {OVERLOAD_QUEUE_CAP}): {} offered = {} completed + {} shed; \
         admitted p99 TTFT {:.0} ms (limit {OVERLOAD_TTFT_P99_LIMIT_MS} ms)",
        over.offered_qps,
        over.submitted,
        over.completed,
        over.shed,
        over.ttft_p99_ms,
    );

    // chunked-prefill leg: paper config, calibrated Poisson arrivals,
    // one giant prompt mid-stream — monolithic vs --prefill-chunk
    let paper = ModelConfig::builtin("paper", "consmax")?;
    let paper_store = ParamStore::init(&paper, 0)?;
    let short_s = calibrate_short_s(&paper, &paper_store)?;
    let mean_gap_s = (2.0 * short_s).max(0.002);
    let chunk_sched = chunk_schedule(mean_gap_s, 17);
    let mono = run_feature(&paper, &paper_store, &chunk_sched, None, None)?;
    let chunked = run_feature(
        &paper,
        &paper_store,
        &chunk_sched,
        Some(CHUNK_SIZE),
        None,
    )?;
    let chunk_bitwise = mono.tokens == chunked.tokens;
    let chunking_ok = chunked.ttft_p99_ms < mono.ttft_p99_ms
        && chunked.ttft_p99_ms <= CHUNK_TTFT_P99_LIMIT_MS
        && chunk_bitwise;
    println!(
        "\nchunked prefill ({}, {} reqs, one {}-token prompt mid-stream, \
         ~{:.0} ms mean arrival gap): p99 TTFT {:.0} ms chunked (chunk \
         {CHUNK_SIZE}) vs {:.0} ms monolithic (limit \
         {CHUNK_TTFT_P99_LIMIT_MS} ms; bitwise tokens: {chunk_bitwise})",
        paper.key,
        CHUNK_REQS,
        CHUNK_LONG_PROMPT,
        mean_gap_s * 1e3,
        chunked.ttft_p99_ms,
        mono.ttft_p99_ms,
    );

    // accept-heavy speculation leg: rigged target + rigged tiny draft
    let mut rig_target = ParamStore::init(&paper, 0)?;
    rig_always_argmax(&mut rig_target, RIG_TOKEN, RIG_LAMBDA);
    let tiny_draft_cfg = ModelConfig::builtin("tiny", "consmax")?;
    let mut rig_draft = ParamStore::init(&tiny_draft_cfg, 0)?;
    rig_always_argmax(&mut rig_draft, RIG_TOKEN, RIG_LAMBDA);
    let spec_sched = spec_schedule();
    let no_spec = run_feature(&paper, &rig_target, &spec_sched, None, None)?;
    let with_spec = run_feature(
        &paper,
        &rig_target,
        &spec_sched,
        None,
        Some((SPEC_DRAFT_K, &tiny_draft_cfg, &rig_draft)),
    )?;
    let spec_speedup = with_spec.tok_s / no_spec.tok_s;
    let acceptance =
        with_spec.accepted as f64 / (with_spec.proposed.max(1)) as f64;
    let spec_bitwise = no_spec.tokens == with_spec.tokens;
    let spec_ok = spec_speedup >= MIN_SPEC_SPEEDUP
        && acceptance >= MIN_ACCEPTANCE
        && with_spec.proposed > 0
        && spec_bitwise;
    println!(
        "speculative decode ({} target, tiny draft-k={SPEC_DRAFT_K}, \
         accept-heavy rig): {:.0} tok/s vs {:.0} tok/s plain = \
         {spec_speedup:.2}x (floor {MIN_SPEC_SPEEDUP}x); acceptance \
         {:.1}% (floor {:.0}%); bitwise tokens: {spec_bitwise}",
        paper.key,
        with_spec.tok_s,
        no_spec.tok_s,
        100.0 * acceptance,
        100.0 * MIN_ACCEPTANCE,
    );

    let doc = Json::from_pairs([
        ("bench".to_string(), Json::from("serve")),
        ("config".to_string(), Json::from(cfg.key.as_str())),
        ("normalizer".to_string(), Json::from(cfg.normalizer.as_str())),
        ("requests".to_string(), Json::from(N_REQUESTS)),
        ("short_new".to_string(), Json::from(SHORT_NEW)),
        ("long_new".to_string(), Json::from(LONG_NEW)),
        ("slots".to_string(), Json::from(SLOTS)),
        (
            "threads".to_string(),
            Json::from(consmax::runtime::parallel::current_threads()),
        ),
        ("static".to_string(), stats_json(&stat)),
        ("continuous".to_string(), stats_json(&cont)),
        ("speedup".to_string(), Json::from(speedup)),
        ("min_speedup_required".to_string(), Json::from(MIN_SPEEDUP)),
        ("ttft_p99_lower".to_string(), Json::from(ttft_ok)),
        (
            "overload".to_string(),
            Json::from_pairs([
                ("factor".to_string(), Json::from(OVERLOAD_FACTOR)),
                ("queue_cap".to_string(), Json::from(OVERLOAD_QUEUE_CAP)),
                ("offered_qps".to_string(), Json::from(over.offered_qps)),
                ("wall_s".to_string(), Json::from(over.wall_s)),
                (
                    "submitted".to_string(),
                    Json::from(over.submitted as f64),
                ),
                ("admitted".to_string(), Json::from(over.admitted as f64)),
                ("shed".to_string(), Json::from(over.shed as f64)),
                (
                    "completed".to_string(),
                    Json::from(over.completed as f64),
                ),
                ("ttft_p99_ms".to_string(), Json::from(over.ttft_p99_ms)),
                (
                    "ttft_p99_limit_ms".to_string(),
                    Json::from(OVERLOAD_TTFT_P99_LIMIT_MS),
                ),
                (
                    "no_silent_drops".to_string(),
                    Json::from(no_silent_drops),
                ),
            ]),
        ),
        ("overload_ok".to_string(), Json::from(overload_ok)),
        (
            "chunking".to_string(),
            Json::from_pairs([
                ("config".to_string(), Json::from(paper.key.as_str())),
                ("chunk".to_string(), Json::from(CHUNK_SIZE)),
                ("requests".to_string(), Json::from(CHUNK_REQS)),
                (
                    "long_prompt_tokens".to_string(),
                    Json::from(CHUNK_LONG_PROMPT),
                ),
                ("mean_gap_ms".to_string(), Json::from(mean_gap_s * 1e3)),
                (
                    "chunked_ttft_p99_ms".to_string(),
                    Json::from(chunked.ttft_p99_ms),
                ),
                (
                    "monolithic_ttft_p99_ms".to_string(),
                    Json::from(mono.ttft_p99_ms),
                ),
                (
                    "ttft_p99_limit_ms".to_string(),
                    Json::from(CHUNK_TTFT_P99_LIMIT_MS),
                ),
                ("chunked_wall_s".to_string(), Json::from(chunked.wall_s)),
                ("monolithic_wall_s".to_string(), Json::from(mono.wall_s)),
                ("bitwise_tokens".to_string(), Json::from(chunk_bitwise)),
            ]),
        ),
        ("chunking_ok".to_string(), Json::from(chunking_ok)),
        (
            "speculation".to_string(),
            Json::from_pairs([
                ("config".to_string(), Json::from(paper.key.as_str())),
                ("draft_k".to_string(), Json::from(SPEC_DRAFT_K)),
                ("requests".to_string(), Json::from(SPEC_REQS)),
                ("max_new".to_string(), Json::from(SPEC_NEW)),
                ("spec_tok_s".to_string(), Json::from(with_spec.tok_s)),
                ("no_spec_tok_s".to_string(), Json::from(no_spec.tok_s)),
                ("spec_speedup".to_string(), Json::from(spec_speedup)),
                (
                    "min_spec_speedup_required".to_string(),
                    Json::from(MIN_SPEC_SPEEDUP),
                ),
                ("acceptance_rate".to_string(), Json::from(acceptance)),
                (
                    "min_acceptance_required".to_string(),
                    Json::from(MIN_ACCEPTANCE),
                ),
                (
                    "proposed".to_string(),
                    Json::from(with_spec.proposed as f64),
                ),
                (
                    "accepted".to_string(),
                    Json::from(with_spec.accepted as f64),
                ),
                ("bitwise_tokens".to_string(), Json::from(spec_bitwise)),
            ]),
        ),
        ("spec_ok".to_string(), Json::from(spec_ok)),
    ]);
    std::fs::write("BENCH_serve.json", doc.to_string())?;
    println!("wrote BENCH_serve.json");

    if speedup < MIN_SPEEDUP || !ttft_ok {
        eprintln!(
            "FAIL: continuous batching must clear {MIN_SPEEDUP}x static \
             token throughput with lower p99 TTFT (got {speedup:.2}x, \
             ttft_p99_lower={ttft_ok}) — see table above"
        );
        std::process::exit(1);
    }
    if !overload_ok {
        eprintln!(
            "FAIL: under {OVERLOAD_FACTOR}x overload the server must shed \
             (shed={}, want >0), account for every request \
             (completed {} + shed {} == submitted {}, admitted {} == \
             completed), and keep admitted p99 TTFT <= \
             {OVERLOAD_TTFT_P99_LIMIT_MS} ms (got {:.0} ms)",
            over.shed,
            over.completed,
            over.shed,
            over.submitted,
            over.admitted,
            over.ttft_p99_ms,
        );
        std::process::exit(1);
    }
    if !chunking_ok {
        eprintln!(
            "FAIL: chunked prefill must beat monolithic p99 TTFT under the \
             long+short mix and stay under {CHUNK_TTFT_P99_LIMIT_MS} ms \
             with bitwise tokens (chunked {:.0} ms vs monolithic {:.0} ms, \
             bitwise={chunk_bitwise})",
            chunked.ttft_p99_ms,
            mono.ttft_p99_ms,
        );
        std::process::exit(1);
    }
    if !spec_ok {
        eprintln!(
            "FAIL: accept-heavy speculation must clear \
             {MIN_SPEC_SPEEDUP}x decode tok/s at >= {:.0}% acceptance with \
             bitwise tokens (got {spec_speedup:.2}x, acceptance {:.1}%, \
             proposed {}, bitwise={spec_bitwise})",
            100.0 * MIN_ACCEPTANCE,
            100.0 * acceptance,
            with_spec.proposed,
        );
        std::process::exit(1);
    }
    Ok(())
}
