//! Native training parity gate: the paper's Fig 6 claim — ConSmax
//! trains to softmax-level loss — as a CI-enforced number
//! (EXPERIMENTS.md §Native training, DESIGN.md §Training seam).
//!
//! Run: `cargo bench --bench train_gate` (native, no artifacts). Both
//! normalizers train from the same seed on the same in-tree corpus for
//! the same step budget through the native backward + AdamW stack,
//! then score [`EVAL_BATCHES`] validation batches. The gate fails if
//! either run failed to learn (final train loss not below initial) or
//! if the ConSmax-vs-softmax eval-loss gap exceeds [`DELTA_GATE_NATS`].
//!
//! Emits `BENCH_train.json` and exits non-zero on a breach, so CI
//! cannot ship a backward pass or optimizer change that silently
//! breaks convergence parity.

use consmax::config::ModelConfig;
use consmax::coordinator::{NativeTrainer, ParamStore, TrainOptions};
use consmax::data::{BatchSampler, ByteTokenizer, Corpus};
use consmax::metrics::perplexity;
use consmax::util::bench::print_table;
use consmax::util::json::Json;

/// Shared step budget. 60 steps of the tiny config put both curves
/// well below their ln(256) ≈ 5.55 start while keeping the gate a
/// sub-minute CI step; the parity claim is about matched budgets, not
/// full convergence.
const STEPS: usize = 60;
/// Validation batches scored per normalizer (same count as `eval`).
const EVAL_BATCHES: usize = 8;
/// Parity gate: |consmax eval loss − softmax eval loss| must stay
/// under this many nats after the same step budget. Measured gaps on
/// the in-tree corpus sit well under 0.1 nats either way; 0.25 leaves
/// room for seed-to-seed variance without letting a broken normalizer
/// gradient through.
const DELTA_GATE_NATS: f64 = 0.25;
const SEED: u64 = 0;

struct GateRow {
    normalizer: &'static str,
    initial_train_loss: f64,
    final_train_loss: f64,
    eval_loss: f64,
}

fn train_one(normalizer: &'static str) -> anyhow::Result<GateRow> {
    let cfg = ModelConfig::builtin("tiny", normalizer)?;
    let corpus = Corpus::tiny();
    let (train_text, val_text) = corpus.split();
    let tok = ByteTokenizer;
    let train =
        BatchSampler::new(tok.encode(train_text), cfg.train_batch, cfg.ctx, SEED);
    let val =
        BatchSampler::new(tok.encode(val_text), cfg.train_batch, cfg.ctx, SEED);
    let store = ParamStore::init(&cfg, SEED)?;
    let mut tr = NativeTrainer::new(cfg, store, train, Some(val));
    let report = tr.train(&TrainOptions {
        steps: STEPS,
        log_every: 10,
        eval_every: 0,
        eval_batches: EVAL_BATCHES,
        trace_params: false,
        checkpoint: None,
    })?;
    let initial = tr
        .metrics
        .get("train_loss")
        .and_then(|s| s.points.first().map(|&(_, v)| v))
        .unwrap_or(f64::NAN);
    Ok(GateRow {
        normalizer,
        initial_train_loss: initial,
        final_train_loss: report.final_loss,
        eval_loss: tr.evaluate(EVAL_BATCHES)?,
    })
}

fn main() -> anyhow::Result<()> {
    let rows = vec![train_one("softmax")?, train_one("consmax")?];

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.normalizer.to_string(),
                format!("{:.4}", r.initial_train_loss),
                format!("{:.4}", r.final_train_loss),
                format!("{:.4}", r.eval_loss),
                format!("{:.2}", perplexity(r.eval_loss)),
            ]
        })
        .collect();
    print_table(
        &format!(
            "Native training parity gate, tiny config ({STEPS} steps, \
             {EVAL_BATCHES} val batches, gate |delta| < {DELTA_GATE_NATS} nats)"
        ),
        &["normalizer", "initial loss", "final loss", "eval loss", "eval ppl"],
        &table,
    );
    let delta = rows[1].eval_loss - rows[0].eval_loss;
    println!("\nConSmax-vs-softmax eval-loss delta: {delta:+.4} nats");

    let mut pairs = vec![
        ("bench".to_string(), Json::from("train")),
        ("steps".to_string(), Json::from(STEPS)),
        ("eval_batches".to_string(), Json::from(EVAL_BATCHES)),
        ("delta_gate_nats".to_string(), Json::from(DELTA_GATE_NATS)),
        ("delta_nats".to_string(), Json::from(delta)),
        (
            "threads".to_string(),
            Json::from(consmax::runtime::parallel::current_threads()),
        ),
    ];
    for r in &rows {
        pairs.push((
            r.normalizer.to_string(),
            Json::from_pairs([
                (
                    "initial_train_loss".to_string(),
                    Json::from(r.initial_train_loss),
                ),
                ("final_train_loss".to_string(), Json::from(r.final_train_loss)),
                ("eval_loss".to_string(), Json::from(r.eval_loss)),
                ("eval_ppl".to_string(), Json::from(perplexity(r.eval_loss))),
            ]),
        ));
    }
    let doc = Json::from_pairs(pairs);
    std::fs::write("BENCH_train.json", doc.to_string())?;
    println!("wrote BENCH_train.json");

    let mut failed = false;
    for r in &rows {
        if !(r.final_train_loss < r.initial_train_loss) {
            eprintln!(
                "FAIL: {} did not learn (loss {:.4} -> {:.4} over {STEPS} \
                 steps) — the native backward/optimizer stack is broken",
                r.normalizer, r.initial_train_loss, r.final_train_loss
            );
            failed = true;
        }
    }
    if !(delta.abs() < DELTA_GATE_NATS) {
        eprintln!(
            "FAIL: ConSmax-vs-softmax eval-loss delta {delta:+.4} nats \
             breaches the {DELTA_GATE_NATS}-nat gate — Fig 6 convergence \
             parity no longer holds on the native stack"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!(
        "PASS: both normalizers learn and the eval-loss delta is within \
         {DELTA_GATE_NATS} nats"
    );
    Ok(())
}
