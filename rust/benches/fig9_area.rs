//! Bench: regenerate **Fig 9** — cell-area breakdown per design under
//! both EDA flows, plus the Fmax comparison, at 16nm and 130nm.
//!
//! Run: `cargo bench --bench fig9_area`

use consmax::hw::{fig9, TechNode};
use consmax::util::bench::{print_table, Bencher};

fn main() {
    for node in [TechNode::Fin16, TechNode::Sky130] {
        let entries = fig9(node, 256);
        let mut rows = Vec::new();
        for e in &entries {
            let total: f64 = e.breakdown_um2.iter().map(|(_, v)| v).sum();
            for (class, um2) in &e.breakdown_um2 {
                rows.push(vec![
                    e.design.clone(),
                    e.flow.clone(),
                    class.to_string(),
                    format!("{um2:.0}"),
                    format!("{:.1}%", um2 / total * 100.0),
                ]);
            }
        }
        print_table(
            &format!("Fig 9(a/b): area breakdown @ {node:?}"),
            &["design", "flow", "class", "area um2", "share"],
            &rows,
        );

        let fmax_rows: Vec<Vec<String>> = entries
            .iter()
            .map(|e| {
                vec![
                    e.design.clone(),
                    e.flow.clone(),
                    format!("{:.0}", e.fmax_mhz),
                ]
            })
            .collect();
        print_table(
            &format!("Fig 9(c): Fmax by EDA flow @ {node:?} \
                      (paper 16nm: ConSmax 1250/2000, Softermax 1111/1000, Softmax 909/500)"),
            &["design", "flow", "Fmax MHz"],
            &fmax_rows,
        );
    }

    println!();
    let mut b = Bencher::new();
    b.bench("fig9(16nm, both flows)", || fig9(TechNode::Fin16, 256));
}
