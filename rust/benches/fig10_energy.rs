//! Bench: regenerate **Fig 10** — energy-per-op vs frequency U-curves
//! with the optimum-energy operating points, per design, both nodes and
//! flows.
//!
//! Run: `cargo bench --bench fig10_energy`

use consmax::hw::{fig10, EdaFlow, TechNode};
use consmax::util::bench::{print_table, Bencher};

fn main() {
    for (node, flow) in [
        (TechNode::Fin16, EdaFlow::Proprietary),
        (TechNode::Fin16, EdaFlow::OpenSource),
        (TechNode::Sky130, EdaFlow::Proprietary),
    ] {
        let series = fig10(node, flow, 256, 10);
        let mut rows = Vec::new();
        for (name, sweep, opt) in &series {
            for p in sweep {
                rows.push(vec![
                    name.clone(),
                    format!("{:.0}", p.freq_mhz),
                    format!("{:.2}", p.voltage),
                    format!("{:.3}", p.energy_pj_per_elem),
                ]);
            }
            rows.push(vec![
                format!("{name} OPTIMUM"),
                format!("{:.0}", opt.freq_mhz),
                format!("{:.2}", opt.voltage),
                format!("{:.3}", opt.energy_pj_per_elem),
            ]);
        }
        print_table(
            &format!(
                "Fig 10 @ {node:?}/{flow:?} (paper 16nm optima: ConSmax 0.2 pJ \
                 @666 MHz, Softermax 0.7 @666, Softmax 1.5 @714)"
            ),
            &["design", "MHz", "V", "pJ/elem"],
            &rows,
        );
    }

    println!();
    let mut b = Bencher::new();
    b.bench("fig10 sweep (3 designs x 200 points)", || {
        fig10(TechNode::Fin16, EdaFlow::Proprietary, 256, 200)
    });
}
