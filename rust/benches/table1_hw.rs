//! Bench: regenerate **Table I** (the paper's headline hardware
//! comparison) under both EDA flows and time the synthesis estimator.
//! The printed tables ARE the reproduced artifact; timings confirm the
//! estimator is cheap enough to sweep.
//!
//! Run: `cargo bench --bench table1_hw`

use consmax::hw::report::paper_table1_reference;
use consmax::hw::{savings, table1, EdaFlow};
use consmax::util::bench::{print_table, Bencher};

fn main() {
    for flow in [EdaFlow::Proprietary, EdaFlow::OpenSource] {
        let rows = table1(flow, 256);
        let refs = paper_table1_reference();
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                let node = if r.corner.starts_with("16nm") { "16nm" } else { "130nm" };
                let p = refs
                    .iter()
                    .find(|(d, n, _)| *d == r.design && *n == node)
                    .map(|(_, _, v)| *v)
                    .unwrap_or([f64::NAN; 4]);
                vec![
                    r.design.clone(),
                    r.corner.clone(),
                    format!("{:.0} ({:.0})", r.fmax_mhz, p[0]),
                    format!("{:.5} ({})", r.area_mm2, p[1]),
                    format!("{:.2} ({})", r.power_mw, p[2]),
                    format!("{:.2} ({})", r.opt_energy_pj, p[3]),
                ]
            })
            .collect();
        print_table(
            &format!("Table I, {flow:?} flow — measured (paper reference)"),
            &["design", "corner", "Fmax MHz", "area mm2", "power mW", "opt E pJ"],
            &table,
        );
        let s: Vec<Vec<String>> = savings(&rows)
            .iter()
            .map(|s| {
                vec![
                    s.corner.clone(),
                    s.vs.clone(),
                    format!("{:.2}x", s.power_ratio),
                    format!("{:.2}x", s.area_ratio),
                ]
            })
            .collect();
        print_table(
            "savings (paper: 3.35x/2.75x vs Softermax @16nm; 3.15x/4.14x open flow)",
            &["corner", "vs", "power", "area"],
            &s,
        );
    }

    println!();
    let mut b = Bencher::new();
    b.bench("table1(both nodes, 3 designs)", || {
        table1(EdaFlow::Proprietary, 256)
    });
    b.bench("table1 @ seq 8192", || table1(EdaFlow::Proprietary, 8192));
}
