//! Decode-engine throughput: KV-cached incremental decode vs the
//! recompute oracle, at ctx-length prompts, batch 1/4/8 — the serving
//! latency lever of the KV-engine PR (EXPERIMENTS.md §Decode
//! throughput).
//!
//! Run: `cargo bench --bench decode_bench` (no artifacts, no Python).
//! Emits machine-readable results to `BENCH_decode.json` in the working
//! directory and exits non-zero if the KV engine fails to clear a 5×
//! tokens/s speedup over recompute — CI smoke-runs this so the artifact
//! and the speedup claim cannot rot.

use consmax::config::ModelConfig;
use consmax::coordinator::{DecodeMode, Generator, ParamStore};
use consmax::util::bench::{print_table, Bencher};
use consmax::util::json::Json;

/// Tokens generated per request; prompts fill the rest of ctx.
const MAX_NEW: usize = 16;
/// The speedup floor the KV engine must clear (acceptance criterion).
const MIN_SPEEDUP: f64 = 5.0;

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::builtin("tiny", "consmax")?;
    let store = ParamStore::init(&cfg, 0)?;

    // ctx-length prompt: encode_prompts clamps it to ctx - MAX_NEW, so
    // every request enters decode with a full KV budget
    let prompt: String = "The constant softmax replaces the row reduction "
        .chars()
        .cycle()
        .take(cfg.ctx * 2)
        .collect();

    let mut b = Bencher::coarse();
    b.min_samples = 3;

    let mut rows = Vec::new();
    let mut cases = Vec::new();
    let mut all_clear = true;
    for batch in [1usize, 4, 8] {
        let prompts = vec![prompt.clone(); batch];
        let items = (batch * MAX_NEW) as f64;

        let mut rc =
            Generator::native_with(&cfg, &store, 0, DecodeMode::Recompute)?;
        let name = format!("decode recompute b{batch} ({MAX_NEW} new)");
        let rc_stats = b
            .bench(&name, || rc.generate_batch(&prompts, MAX_NEW, 0.0).unwrap())
            .clone();
        let rc_toks = rc_stats.throughput(items);

        let mut kv = Generator::native_with(&cfg, &store, 0, DecodeMode::Kv)?;
        let name = format!("decode kv b{batch} ({MAX_NEW} new)");
        let kv_stats = b
            .bench(&name, || kv.generate_batch(&prompts, MAX_NEW, 0.0).unwrap())
            .clone();
        let kv_toks = kv_stats.throughput(items);

        let speedup = kv_toks / rc_toks;
        all_clear &= speedup >= MIN_SPEEDUP;
        rows.push(vec![
            format!("{batch}"),
            format!("{rc_toks:.0}"),
            format!("{kv_toks:.0}"),
            format!("{speedup:.1}x"),
        ]);
        cases.push(Json::from_pairs([
            ("batch".to_string(), Json::from(batch)),
            ("recompute_tok_s".to_string(), Json::from(rc_toks)),
            ("kv_tok_s".to_string(), Json::from(kv_toks)),
            ("speedup".to_string(), Json::from(speedup)),
            (
                "recompute_median_ns".to_string(),
                Json::from(rc_stats.median_ns),
            ),
            ("kv_median_ns".to_string(), Json::from(kv_stats.median_ns)),
        ]));
    }

    print_table(
        &format!(
            "Decode throughput, {} (ctx {}, prompt {} toks, {} new)",
            cfg.key,
            cfg.ctx,
            cfg.ctx - MAX_NEW,
            MAX_NEW
        ),
        &["batch", "recompute tok/s", "kv tok/s", "speedup"],
        &rows,
    );

    let doc = Json::from_pairs([
        ("bench".to_string(), Json::from("decode")),
        ("config".to_string(), Json::from(cfg.key.as_str())),
        ("normalizer".to_string(), Json::from(cfg.normalizer.as_str())),
        ("ctx".to_string(), Json::from(cfg.ctx)),
        ("prompt_tokens".to_string(), Json::from(cfg.ctx - MAX_NEW)),
        ("max_new".to_string(), Json::from(MAX_NEW)),
        ("min_speedup_required".to_string(), Json::from(MIN_SPEEDUP)),
        ("cases".to_string(), Json::Arr(cases)),
    ]);
    std::fs::write("BENCH_decode.json", doc.to_string())?;
    b.save_json(std::path::Path::new("BENCH_decode_raw.jsonl"))?;
    println!("\nwrote BENCH_decode.json (+ BENCH_decode_raw.jsonl)");

    if !all_clear {
        eprintln!(
            "FAIL: KV decode did not clear the {MIN_SPEEDUP}x speedup floor \
             over recompute (see table above)"
        );
        std::process::exit(1);
    }
    Ok(())
}
