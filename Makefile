# Repo-level targets. `make artifacts` is the command every "run `make
# artifacts`" message in the Rust crate refers to: it lowers the JAX entry
# points to HLO text + manifest + golden vectors for the PJRT backend.
# The default Rust build needs none of this (see rust/README.md).

.PHONY: artifacts bench-artifacts build test bench fmt clippy python-test clean-artifacts

ARTIFACTS_DIR ?= ../rust/artifacts
BENCH_JSON_DIR ?= rust/artifacts/bench

artifacts: bench-artifacts
	cd python && python -m compile.aot --out $(ARTIFACTS_DIR)

# Run the native perf benches (no Python needed) and collect their
# machine-readable results next to the AOT artifacts. All six benches
# enforce hard floors (KV >= 5x recompute; tiled matmul >= 2x naive;
# continuous batching >= 1.5x static serving throughput; fp16/int8
# paging >= 2x/3.5x dense resident requests at fixed memory; int8
# serving within 0.25 nats of f32 eval loss; native ConSmax-vs-softmax
# training parity within 0.25 nats at a matched step budget; under 2x
# overload the server sheds instead of queuing unboundedly with p99
# TTFT of admitted requests bounded and zero silent drops), so this
# target is also a perf and accuracy regression gate.
bench-artifacts:
	cd rust && cargo bench --bench decode_bench && cargo bench --bench forward_bench && cargo bench --bench serve_bench && cargo bench --bench kv_bench && cargo bench --bench quant_gate && cargo bench --bench train_gate
	mkdir -p $(BENCH_JSON_DIR)
	cp rust/BENCH_decode.json rust/BENCH_forward.json rust/BENCH_serve.json rust/BENCH_kv.json rust/BENCH_quant.json rust/BENCH_train.json $(BENCH_JSON_DIR)/
	cp rust/BENCH_decode_raw.jsonl rust/BENCH_forward_raw.jsonl $(BENCH_JSON_DIR)/

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench:
	cd rust && cargo bench

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy -- -D warnings

python-test:
	cd python && python -m pytest tests -q

clean-artifacts:
	rm -rf rust/artifacts
