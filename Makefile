# Repo-level targets. `make artifacts` is the command every "run `make
# artifacts`" message in the Rust crate refers to: it lowers the JAX entry
# points to HLO text + manifest + golden vectors for the PJRT backend.
# The default Rust build needs none of this (see rust/README.md).

.PHONY: artifacts build test bench fmt clippy python-test clean-artifacts

ARTIFACTS_DIR ?= ../rust/artifacts

artifacts:
	cd python && python -m compile.aot --out $(ARTIFACTS_DIR)

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench:
	cd rust && cargo bench

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy -- -D warnings

python-test:
	cd python && python -m pytest tests -q

clean-artifacts:
	rm -rf rust/artifacts
